//! Coordinator side of the distributed sweep service.
//!
//! One listener, one reader thread per worker connection, and a single
//! merge loop that owns all fleet state — the consistent-hash ring,
//! the group-ownership table, and the same pre-sized slot table the
//! mpsc streaming engine merges into. Workers stream `(grid index,
//! stats)` rows; the merge loop drops each row into `slots[index]` and
//! the final [`CampaignReport`] reads the slots out in grid order, so
//! the report is byte-identical to `run_sweep_streaming` /
//! `run_sweep_forked` for any worker count, join order, or timing.
//!
//! Fault tolerance is ownership-based: a group belongs to a worker
//! from `Assign` until its `GroupDone` ack. When a connection dies,
//! the worker leaves the ring and exactly its unacknowledged groups
//! are re-dispatched over the survivors (consistent hashing keeps
//! every surviving worker's assignment intact — see
//! [`super::shard`]). A worker joining after dispatch (the rejoin
//! path) enters the ring and picks up any groups orphaned while the
//! ring was empty; duplicate rows from replay overlap merge
//! idempotently into already-filled slots.

use std::collections::BTreeMap;
use std::io::BufReader;
use std::net::{Ipv4Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use anyhow::{anyhow, ensure, Context, Result};

use crate::campaign::{CampaignReport, ScenarioStats};
use crate::coordinator::Twin;

use super::messages::{read_msg, write_msg, Msg, SweepSpec};
use super::shard::{HashRing, DEFAULT_REPLICAS};
use super::worker::{connect_retry, run_worker, WorkerOptions};

/// Where and how the coordinator runs.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Listen address (`--listen`).
    pub listen: SocketAddr,
    /// Workers to wait for before the first dispatch (`--expect`).
    /// Late joiners beyond this are welcome — they enter the ring and
    /// serve the rejoin path.
    pub expect: usize,
    /// Virtual ring points per worker.
    pub replicas: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            listen: SocketAddr::from((Ipv4Addr::LOCALHOST, 7723)),
            expect: 1,
            replicas: DEFAULT_REPLICAS,
        }
    }
}

/// Fleet-side observability for one served sweep (the simulated
/// numbers live in the [`CampaignReport`]; these are about the service
/// itself).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServiceStats {
    /// Connections that completed the `Hello` handshake.
    pub workers_joined: usize,
    /// Connections lost before shutdown (includes crashed workers).
    pub workers_lost: usize,
    /// Group assignments re-dispatched after a loss (or to a rejoiner
    /// after the fleet was empty).
    pub groups_reassigned: usize,
    /// Rows that arrived for an already-filled slot (replay overlap
    /// after a re-dispatch); merged idempotently, never into the
    /// report twice.
    pub duplicate_rows: usize,
}

/// What a reader thread distils each worker connection into.
enum CoEvent {
    Joined { name: String, stream: TcpStream },
    Row { index: u64, stats: ScenarioStats },
    Done { worker: String, group: u64 },
    Lost { name: String },
}

/// Pump one worker connection into the event channel. The write half
/// is handed to the merge loop at `Hello`; any read error or protocol
/// violation afterwards is a `Lost`.
fn reader_loop(stream: TcpStream, tx: mpsc::Sender<CoEvent>) {
    stream.set_nodelay(true).ok();
    let write_half = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let name = match read_msg(&mut reader) {
        Ok(Msg::Hello { worker }) => worker,
        _ => return,
    };
    let joined = CoEvent::Joined {
        name: name.clone(),
        stream: write_half,
    };
    if tx.send(joined).is_err() {
        return;
    }
    loop {
        let ev = match read_msg(&mut reader) {
            Ok(Msg::Row { index, stats }) => CoEvent::Row { index, stats },
            Ok(Msg::GroupDone { group }) => CoEvent::Done {
                worker: name.clone(),
                group,
            },
            _ => break,
        };
        if tx.send(ev).is_err() {
            return;
        }
    }
    let _ = tx.send(CoEvent::Lost { name });
}

/// Assign `group_ids` across the ring and send each owner one `Assign`
/// frame. Workers whose send fails are queued on `pending_lost` for
/// the merge loop to process as a loss. Returns how many groups got an
/// owner (0 on an empty ring — they stay orphaned for a rejoiner).
fn dispatch(
    group_ids: &[usize],
    ring: &HashRing,
    writers: &mut BTreeMap<String, TcpStream>,
    owner: &mut [Option<String>],
    pending_lost: &mut Vec<String>,
) -> usize {
    let mut per: BTreeMap<String, Vec<u64>> = BTreeMap::new();
    for &g in group_ids {
        if let Some(w) = ring.assign_group(g) {
            owner[g] = Some(w.to_string());
            per.entry(w.to_string()).or_default().push(g as u64);
        }
    }
    let mut assigned = 0;
    for (name, groups) in per {
        assigned += groups.len();
        if let Some(stream) = writers.get_mut(&name) {
            if write_msg(stream, &Msg::Assign { groups }).is_err()
                && !pending_lost.contains(&name)
            {
                pending_lost.push(name);
            }
        }
    }
    assigned
}

/// Serve one sweep on an already-bound listener. Blocks until the
/// report is fully merged (or the whole fleet is lost mid-sweep).
fn serve_on(
    listener: TcpListener,
    spec: &SweepSpec,
    expect: usize,
    replicas: usize,
) -> Result<(CampaignReport, ServiceStats)> {
    ensure!(expect >= 1, "coordinator needs --expect >= 1 workers");
    ensure!(!spec.grid.is_empty(), "refusing to serve an empty sweep grid");
    let local = listener.local_addr().context("coordinator local address")?;
    let stop = AtomicBool::new(false);
    let (tx, rx) = mpsc::channel::<CoEvent>();
    thread::scope(|s| {
        let accept_tx = tx.clone();
        let listener_ref = &listener;
        let stop_ref = &stop;
        s.spawn(move || {
            for conn in listener_ref.incoming() {
                if stop_ref.load(Ordering::Relaxed) {
                    break;
                }
                let Ok(stream) = conn else { break };
                let reader_tx = accept_tx.clone();
                s.spawn(move || reader_loop(stream, reader_tx));
            }
        });
        let out = merge_loop(spec, expect, replicas, &rx);
        // Wind down: stop accepting (the self-connect unblocks the
        // accept thread), then shut down any worker that joined too
        // late for the merge loop to have seen it, so its reader
        // thread unblocks before this scope joins.
        stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(local);
        while let Ok(ev) = rx.recv_timeout(Duration::from_millis(200)) {
            if let CoEvent::Joined { stream, .. } = ev {
                let mut late = stream;
                let _ = write_msg(&mut late, &Msg::Shutdown);
            }
        }
        out
    })
}

/// The single-threaded heart of the coordinator: consumes reader
/// events, owns every piece of fleet state, merges rows by grid index.
fn merge_loop(
    spec: &SweepSpec,
    expect: usize,
    replicas: usize,
    rx: &mpsc::Receiver<CoEvent>,
) -> Result<(CampaignReport, ServiceStats)> {
    let groups = spec.grid.work_groups(spec.fork);
    let n = spec.grid.len();
    let mut ring = HashRing::new(replicas);
    let mut writers: BTreeMap<String, TcpStream> = BTreeMap::new();
    // Ownership table: who a group is assigned to until its ack. An
    // orphan (`None` after dispatch) is waiting for a (re)joiner.
    let mut owner: Vec<Option<String>> = vec![None; groups.len()];
    let mut done = vec![false; groups.len()];
    // The same merge the mpsc streaming path does: a pre-sized slot
    // per scenario, filled in any arrival order, read out in grid
    // order.
    let mut slots: Vec<Option<ScenarioStats>> = vec![None; n];
    let mut filled = 0usize;
    let mut stats = ServiceStats::default();
    let mut dispatched = false;
    let mut pending_lost: Vec<String> = Vec::new();

    let outcome: Result<()> = 'merge: {
        while filled < n {
            // Losses discovered while writing (a send into a dead
            // socket) are processed exactly like reader-detected ones.
            let ev = if let Some(name) = pending_lost.pop() {
                CoEvent::Lost { name }
            } else {
                match rx.recv_timeout(Duration::from_millis(500)) {
                    Ok(ev) => ev,
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        if dispatched && writers.is_empty() {
                            break 'merge Err(anyhow!(
                                "entire worker fleet lost with {} of {n} rows outstanding",
                                n - filled
                            ));
                        }
                        // Pre-dispatch: still waiting for the fleet.
                        continue;
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        break 'merge Err(anyhow!("coordinator event stream ended"))
                    }
                }
            };
            match ev {
                CoEvent::Joined { name, stream } => {
                    if writers.contains_key(&name) {
                        // Duplicate identity: refuse the newcomer by
                        // dropping its write half.
                        continue;
                    }
                    let mut stream = stream;
                    if write_msg(&mut stream, &Msg::Spec { spec: spec.clone() }).is_err() {
                        continue; // died during the handshake
                    }
                    ring.add(&name);
                    writers.insert(name.clone(), stream);
                    stats.workers_joined += 1;
                    if !dispatched {
                        if writers.len() >= expect {
                            dispatched = true;
                            let all: Vec<usize> = (0..groups.len()).collect();
                            dispatch(&all, &ring, &mut writers, &mut owner, &mut pending_lost);
                        }
                    } else {
                        // Rejoin path: in-flight groups stay with
                        // their owners (stealing them would waste
                        // replay), but anything orphaned while the
                        // fleet was short goes to the ring now.
                        let orphans: Vec<usize> = (0..groups.len())
                            .filter(|&g| !done[g] && owner[g].is_none())
                            .collect();
                        if !orphans.is_empty() {
                            stats.groups_reassigned += dispatch(
                                &orphans,
                                &ring,
                                &mut writers,
                                &mut owner,
                                &mut pending_lost,
                            );
                        }
                    }
                }
                CoEvent::Row { index, stats: row } => {
                    let i = index as usize;
                    if i >= n {
                        continue; // corrupt row; the group re-acks or re-dispatches
                    }
                    if slots[i].is_none() {
                        slots[i] = Some(row);
                        filled += 1;
                    } else {
                        stats.duplicate_rows += 1;
                    }
                }
                CoEvent::Done { worker, group } => {
                    let g = group as usize;
                    if g < groups.len() && !done[g] {
                        done[g] = true;
                        if owner[g].as_deref() == Some(worker.as_str()) {
                            owner[g] = None;
                        }
                    }
                }
                CoEvent::Lost { name } => {
                    if writers.remove(&name).is_none() {
                        continue; // already processed (or never joined)
                    }
                    ring.remove(&name);
                    stats.workers_lost += 1;
                    let orphaned: Vec<usize> = (0..groups.len())
                        .filter(|&g| !done[g] && owner[g].as_deref() == Some(name.as_str()))
                        .collect();
                    for &g in &orphaned {
                        owner[g] = None;
                    }
                    if dispatched && !orphaned.is_empty() && !ring.is_empty() {
                        stats.groups_reassigned += dispatch(
                            &orphaned,
                            &ring,
                            &mut writers,
                            &mut owner,
                            &mut pending_lost,
                        );
                    }
                }
            }
        }
        Ok(())
    };
    // Shut the fleet down on every exit path so workers (and their
    // reader threads) unblock.
    for stream in writers.values_mut() {
        let _ = write_msg(stream, &Msg::Shutdown);
    }
    outcome?;
    let rows = slots
        .into_iter()
        .map(|s| s.expect("merge loop exited with every slot filled"))
        .collect();
    Ok((CampaignReport { stats: rows }, stats))
}

/// Run the coordinator for one sweep (`leonardo-twin serve`): bind,
/// wait for `cfg.expect` workers, dispatch, merge, shut the fleet
/// down.
pub fn serve(spec: &SweepSpec, cfg: &CoordinatorConfig) -> Result<(CampaignReport, ServiceStats)> {
    let listener = TcpListener::bind(cfg.listen)
        .with_context(|| format!("bind coordinator listener on {}", cfg.listen))?;
    serve_on(listener, spec, cfg.expect, cfg.replicas)
}

/// One-call in-process fleet: a coordinator on an ephemeral loopback
/// port plus `workers` worker threads, each with its own cloned twin
/// and persistent arena — the distributed path the tests, benches and
/// `sweep --workers N` run. `die_after` is the churn hook: worker `k`
/// drops its connection after acknowledging `n` groups for each
/// `(k, n)` entry.
pub fn run_distributed(
    twin: &Twin,
    spec: &SweepSpec,
    workers: usize,
    die_after: &[(usize, usize)],
) -> Result<(CampaignReport, ServiceStats)> {
    ensure!(workers >= 1, "in-process fleet needs at least one worker");
    let listener = TcpListener::bind((Ipv4Addr::LOCALHOST, 0))
        .context("bind in-process fleet listener")?;
    let addr = listener.local_addr().context("in-process fleet address")?;
    thread::scope(|s| {
        let mut fleet = Vec::new();
        for k in 0..workers {
            let die = die_after
                .iter()
                .find(|&&(w, _)| w == k)
                .map(|&(_, n)| n);
            let mut worker_twin = twin.clone();
            fleet.push(s.spawn(move || -> Result<usize> {
                let stream = connect_retry(addr, Duration::from_secs(10))?;
                let opts = WorkerOptions {
                    id: format!("w{k}"),
                    die_after_groups: die,
                };
                run_worker(&mut worker_twin, stream, &opts)
            }));
        }
        // All `workers` threads join before dispatch, so the ring
        // membership — and therefore the assignment — is deterministic.
        let out = serve_on(listener, spec, workers, DEFAULT_REPLICAS);
        for handle in fleet {
            match handle.join() {
                Ok(Ok(_acked)) => {}
                Ok(Err(e)) => {
                    if out.is_ok() {
                        return Err(e.context("in-process worker failed"));
                    }
                }
                Err(panic) => std::panic::resume_unwind(panic),
            }
        }
        out
    })
}
