//! Worker side of the distributed sweep service.
//!
//! A worker is one long-lived connection: it sends `Hello`, receives
//! the [`SweepSpec`], and then replays whatever groups the coordinator
//! assigns on a single persistent [`ReplayRig`] arena — exactly the
//! per-thread arena the local streaming/forked engines keep, so the
//! rows it streams back are byte-identical to the rows a local worker
//! thread would have merged. Every finished group is acknowledged with
//! `GroupDone`; an unacknowledged group is the coordinator's to
//! re-dispatch if this connection dies.

use std::collections::VecDeque;
use std::io::BufReader;
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::campaign::{replay_group, ReplayRig, Scenario};
use crate::coordinator::Twin;

use super::messages::{read_msg, write_msg, Msg};

/// How a worker identifies itself, plus the test-only churn hook.
#[derive(Debug, Clone)]
pub struct WorkerOptions {
    /// Name on the coordinator's consistent-hash ring. Must be unique
    /// per fleet; the in-process fleet uses `w0..wN-1`, the CLI uses
    /// `w{pid}`.
    pub id: String,
    /// Drop the connection (without a goodbye, like a real crash)
    /// after acknowledging this many groups — the worker-churn tests'
    /// way of killing one of three workers mid-sweep. `None` in
    /// production.
    pub die_after_groups: Option<usize>,
}

impl WorkerOptions {
    pub fn named(id: &str) -> Self {
        WorkerOptions {
            id: id.to_string(),
            die_after_groups: None,
        }
    }
}

/// Resolve a `--listen`/`--connect` address, erroring cleanly on
/// garbage instead of panicking deep in the socket stack.
pub fn parse_addr(s: &str) -> Result<SocketAddr> {
    let mut addrs = s
        .to_socket_addrs()
        .with_context(|| format!("bad address '{s}' (want host:port)"))?;
    addrs
        .next()
        .ok_or_else(|| anyhow!("address '{s}' resolved to nothing"))
}

/// Connect with retries over `patience` — CLI workers routinely start
/// before the coordinator's listener is up (the CI step launches all
/// three processes at once).
pub fn connect_retry(addr: SocketAddr, patience: Duration) -> Result<TcpStream> {
    let deadline = Instant::now() + patience;
    loop {
        match TcpStream::connect(addr) {
            Ok(stream) => return Ok(stream),
            Err(e) => {
                if Instant::now() >= deadline {
                    bail!("no coordinator at {addr}: {e}");
                }
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
}

/// Run one worker over an established connection until the coordinator
/// shuts it down (or hangs up). Returns the number of groups this
/// worker acknowledged.
pub fn run_worker(twin: &mut Twin, stream: TcpStream, opts: &WorkerOptions) -> Result<usize> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone().context("clone worker stream")?);
    let mut writer = stream;
    write_msg(
        &mut writer,
        &Msg::Hello {
            worker: opts.id.clone(),
        },
    )?;
    // The expanded sweep: scenarios plus the canonical group numbering,
    // both derived from the spec exactly as the coordinator derives
    // them — the wire only carries group ids.
    let mut job: Option<(Vec<Scenario>, Vec<Vec<usize>>)> = None;
    let mut queue: VecDeque<usize> = VecDeque::new();
    // One persistent arena across every group, like a local worker
    // thread's (armed lazily by `replay_group`, reset between
    // scenarios).
    let mut arena: Option<ReplayRig> = None;
    let mut acked = 0usize;
    loop {
        // A dead coordinator is a normal way for a worker's life to
        // end (the CLI fleet outlives the sweep it served).
        let msg = match read_msg(&mut reader) {
            Ok(m) => m,
            Err(_) => return Ok(acked),
        };
        match msg {
            Msg::Spec { spec } => {
                // The routing policy shapes coupled comm slowdowns, so
                // it must match the submitting twin's fabric.
                twin.net.routing = spec.routing;
                let scenarios = spec.grid.scenarios();
                let groups = spec.grid.work_groups(spec.fork);
                job = Some((scenarios, groups));
                queue.clear();
            }
            Msg::Assign { groups } => {
                for g in groups {
                    queue.push_back(g as usize);
                }
            }
            Msg::Shutdown => return Ok(acked),
            other => bail!("worker {}: unexpected {other:?}", opts.id),
        }
        while let Some(g) = queue.pop_front() {
            let (scenarios, groups) = job
                .as_ref()
                .ok_or_else(|| anyhow!("worker {}: assignment before spec", opts.id))?;
            ensure!(
                g < groups.len(),
                "worker {}: group {g} out of range (grid has {})",
                opts.id,
                groups.len()
            );
            for (index, stats) in replay_group(&mut arena, twin, scenarios, &groups[g]) {
                write_msg(
                    &mut writer,
                    &Msg::Row {
                        index: index as u64,
                        stats,
                    },
                )?;
            }
            write_msg(&mut writer, &Msg::GroupDone { group: g as u64 })?;
            acked += 1;
            if opts.die_after_groups.is_some_and(|n| acked >= n) {
                // Simulated crash: drop the socket with groups still
                // assigned and unacknowledged.
                return Ok(acked);
            }
        }
    }
}

/// CLI entry point (`leonardo-twin work --connect HOST:PORT`): build a
/// LEONARDO twin, join the fleet, replay until shut down.
pub fn work(connect: &str) -> Result<()> {
    let addr = parse_addr(connect)?;
    let stream = connect_retry(addr, Duration::from_secs(30))?;
    let mut twin = Twin::leonardo();
    let opts = WorkerOptions::named(&format!("w{}", std::process::id()));
    let acked = run_worker(&mut twin, stream, &opts)?;
    eprintln!("worker {}: replayed {acked} group(s)", opts.id);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_addr_accepts_host_port_and_rejects_garbage() {
        assert_eq!(
            parse_addr("127.0.0.1:7723").unwrap(),
            "127.0.0.1:7723".parse::<SocketAddr>().unwrap()
        );
        assert!(parse_addr("127.0.0.1").is_err(), "missing port");
        assert!(parse_addr("not an address").is_err());
        assert!(parse_addr("127.0.0.1:notaport").is_err());
        assert!(parse_addr("").is_err());
    }

    #[test]
    fn connect_retry_gives_up_with_context() {
        // Loopback port 1 refuses immediately (nothing may listen
        // there); patience zero turns that refusal into the error.
        let addr: SocketAddr = "127.0.0.1:1".parse().unwrap();
        let err = connect_retry(addr, Duration::from_millis(0)).unwrap_err();
        assert!(err.to_string().contains("no coordinator"));
    }
}
