//! Worker side of the distributed sweep service.
//!
//! A worker is one long-lived connection driving N cores: it sends
//! `Hello`, receives job-tagged [`SweepSpec`]s, and *pulls* work —
//! `Next` requests credit for as many groups as its replay pipeline
//! has room for ([`WorkerOptions::threads`] ×
//! [`WorkerOptions::prefetch`]), the coordinator answers with `Grant`
//! (or an unsolicited `Assign` in static dispatch mode — the worker
//! treats both identically). Granted groups feed an in-process queue
//! consumed by a pool of replay threads, each owning a persistent
//! [`ReplayRig`] arena — exactly the per-thread arena
//! [`crate::campaign::run_sweep_streaming`] keeps, so the rows
//! streamed back are byte-identical to the rows a local worker thread
//! would have merged. Every finished group goes back as one `RowBatch`
//! frame (all member rows + the completion ack in a single write);
//! an unbatched group is the coordinator's to re-dispatch if this
//! connection dies.
//!
//! The connection's *write half stays on one thread*: replay threads
//! hand finished groups back over a channel and the protocol loop is
//! the only writer, which keeps frame order (and the chaos harness's
//! operation counting) deterministic.
//!
//! Liveness runs both ways. The socket carries a read timeout, the
//! worker answers every `Ping` with `Pong`, and a coordinator that
//! goes silent past [`WorkerOptions::patience`] is a clear
//! "coordinator vanished" error — never a hang on a dead socket. The
//! CLI worker goes one further: [`run_worker_resilient`] reconnects
//! with seeded exponential backoff and rejoins the fleet under the
//! same name after a coordinator restart, so a fleet survives its
//! coordinator the same way the coordinator survives its fleet.
//!
//! [`WorkerOptions::chaos`] arms the wire-fault harness: both halves
//! of the connection get wrapped in a seeded
//! [`FaultyTransport`](super::chaos::FaultyTransport), making this
//! worker deterministically misbehave mid-protocol — the probe the
//! chaos suite and the CI chaos step point at a live coordinator.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::campaign::{replay_group, ReplayRig, Scenario, ScenarioStats};
use crate::coordinator::Twin;
use crate::topology::Routing;

use super::chaos::{xorshift, FaultPlan, FaultyTransport};
use super::messages::{read_msg_patient, write_msg, Msg};

/// How a worker identifies itself and how patient it is, plus the
/// test-only churn and chaos hooks.
#[derive(Debug, Clone)]
pub struct WorkerOptions {
    /// Name on the coordinator's consistent-hash ring. Must be unique
    /// per fleet; the in-process fleet uses `w0..wN-1`, the CLI uses
    /// `w{pid}`.
    pub id: String,
    /// Drop the connection (without a goodbye, like a real crash)
    /// after acknowledging this many groups — the worker-churn tests'
    /// way of killing one of three workers mid-sweep. `None` in
    /// production.
    pub die_after_groups: Option<usize>,
    /// Socket read poll: bounds how late the worker notices silence
    /// or shutdown, not how long it waits overall.
    pub poll: Duration,
    /// How long the coordinator may stay completely silent (its
    /// heartbeat normally arrives far more often) before this worker
    /// declares it vanished and bails instead of blocking forever.
    pub patience: Duration,
    /// Seeded wire-fault injection: wrap both connection halves in a
    /// [`FaultyTransport`](super::chaos::FaultyTransport) running
    /// [`FaultPlan::seeded`] schedules derived from this seed.
    pub chaos: Option<u64>,
    /// Replay threads (`work --threads`): the worker's pool of
    /// persistent arenas, all fed through this one connection. 1 (the
    /// default) replays groups on a single arena like the PR 8 worker.
    pub threads: usize,
    /// Prefetch window per replay thread (`work --prefetch`): the
    /// worker keeps up to `threads × prefetch` groups granted-or-
    /// running so the pipe never runs dry between a `RowBatch` and the
    /// next `Grant`. Clamped to at least 1.
    pub prefetch: usize,
}

impl WorkerOptions {
    pub fn named(id: &str) -> Self {
        WorkerOptions {
            id: id.to_string(),
            die_after_groups: None,
            poll: Duration::from_millis(100),
            patience: Duration::from_secs(30),
            chaos: None,
            threads: 1,
            prefetch: 2,
        }
    }
}

/// Resolve a `--listen`/`--connect` address, erroring cleanly on
/// garbage instead of panicking deep in the socket stack.
pub fn parse_addr(s: &str) -> Result<SocketAddr> {
    let mut addrs = s
        .to_socket_addrs()
        .with_context(|| format!("bad address '{s}' (want host:port)"))?;
    addrs
        .next()
        .ok_or_else(|| anyhow!("address '{s}' resolved to nothing"))
}

/// FNV-1a over a name — the seed source for retry jitter, so every
/// worker (and every address) jitters differently but reproducibly.
fn fnv_seed(s: &str) -> u64 {
    s.bytes()
        .fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
            (h ^ u64::from(b)).wrapping_mul(0x0000_0100_0000_01b3)
        })
}

/// Retry delay for `attempt` (0-based): exponential from 10 ms,
/// capped at 1 s, with deterministic seeded jitter in the upper half
/// of the window so a fleet restarting together doesn't reconnect in
/// lockstep.
pub fn backoff_delay(attempt: u32, seed: u64) -> Duration {
    const BASE_MS: u64 = 10;
    const CAP_MS: u64 = 1_000;
    let full = BASE_MS.saturating_mul(1u64 << attempt.min(10)).min(CAP_MS);
    let r = xorshift(seed ^ u64::from(attempt).wrapping_mul(0x9e37_79b9_7f4a_7c15));
    let jitter = r % (full / 2 + 1);
    Duration::from_millis(full / 2 + jitter)
}

/// Connect with retries over `patience` — CLI workers routinely start
/// before the coordinator's listener is up (the CI step launches all
/// the processes at once). Jitter is seeded from the address; workers
/// that want per-identity spread use [`connect_retry_seeded`].
pub fn connect_retry(addr: SocketAddr, patience: Duration) -> Result<TcpStream> {
    connect_retry_seeded(addr, patience, fnv_seed(&addr.to_string()))
}

/// [`connect_retry`] with an explicit jitter seed.
pub fn connect_retry_seeded(
    addr: SocketAddr,
    patience: Duration,
    seed: u64,
) -> Result<TcpStream> {
    let deadline = Instant::now() + patience;
    let mut attempt = 0u32;
    loop {
        match TcpStream::connect(addr) {
            Ok(stream) => return Ok(stream),
            Err(e) => {
                let now = Instant::now();
                if now >= deadline {
                    bail!("no coordinator at {addr}: {e}");
                }
                let delay = backoff_delay(attempt, seed).min(deadline - now);
                std::thread::sleep(delay);
                attempt += 1;
            }
        }
    }
}

/// Run one worker over an established connection until the
/// coordinator shuts it down. Returns the number of groups this
/// worker acknowledged. A coordinator that hangs up or goes silent is
/// an *error* now (the resilient wrapper turns it into a rejoin; a
/// bare call surfaces it to the operator).
pub fn run_worker(twin: &mut Twin, stream: TcpStream, opts: &WorkerOptions) -> Result<usize> {
    stream.set_nodelay(true).ok();
    stream
        .set_read_timeout(Some(opts.poll))
        .context("arm worker read timeout")?;
    stream.set_write_timeout(Some(Duration::from_secs(10))).ok();
    let reader = stream.try_clone().context("clone worker stream")?;
    let writer = stream;
    match opts.chaos {
        Some(seed) => {
            // Independent schedules per direction: reads and writes
            // misbehave at their own deterministic positions.
            let reader = FaultyTransport::new(reader, FaultPlan::seeded(seed ^ 0x5245_4144));
            let writer = FaultyTransport::new(writer, FaultPlan::seeded(seed));
            run_worker_io(twin, reader, writer, opts)
        }
        None => run_worker_io(twin, reader, writer, opts),
    }
}

/// One job's expanded sweep, shared read-only by every replay thread:
/// scenarios plus the canonical group numbering, both derived from the
/// spec exactly as the coordinator derives them — the wire only
/// carries group ids.
struct JobCtx {
    job: u64,
    /// The routing policy shapes coupled comm slowdowns, so it must
    /// match the submitting twin's fabric; each replay thread stamps
    /// it onto its own twin clone.
    routing: Routing,
    scenarios: Vec<Scenario>,
    groups: Vec<Vec<usize>>,
}

/// What the protocol loop multiplexes: inbound frames, the reader
/// dying, and finished groups coming back from the replay pool.
enum WorkerEv {
    In(Msg),
    ReadDead(anyhow::Error),
    Done {
        job: u64,
        group: u64,
        rows: Vec<(u64, ScenarioStats)>,
    },
}

/// Top up outstanding credit to the prefetch window: ask for exactly
/// the room the replay pipeline has left (granted-or-running groups
/// plus credit already requested count against it).
fn request_more<W: Write>(
    writer: &mut W,
    job: u64,
    window: usize,
    inflight: usize,
    asked: &mut usize,
) -> Result<()> {
    let want = window.saturating_sub(inflight + *asked);
    if want > 0 {
        write_msg(writer, &Msg::Next { job, want: want as u64 })?;
        *asked += want;
    }
    Ok(())
}

/// The transport-generic worker body ([`run_worker`] minus the socket
/// setup) — the seam where the chaos harness slips its faulty
/// transports under an otherwise honest worker. Public so the chaos
/// suite can pin a [`FaultPlan`] at an exact protocol position instead
/// of deriving one from a seed.
///
/// Three kinds of thread run under one scope: a reader pumping frames
/// off `reader`, [`WorkerOptions::threads`] replay threads each with a
/// twin clone and a persistent arena consuming an in-process group
/// queue, and the protocol loop here — the *only* writer — which turns
/// `Grant`/`Assign` into queued tasks and finished groups into
/// `RowBatch` frames, topping up credit with `Next` as the pipeline
/// drains. With no pings in flight the write sequence is fully
/// deterministic (`Hello`, `Next`, then `RowBatch`/`Next` pairs),
/// which is what the pinned chaos tests aim their faults at.
pub fn run_worker_io<R, W>(
    twin: &mut Twin,
    reader: R,
    mut writer: W,
    opts: &WorkerOptions,
) -> Result<usize>
where
    R: Read + Send,
    W: Write,
{
    write_msg(
        &mut writer,
        &Msg::Hello {
            worker: opts.id.clone(),
        },
    )?;
    let threads = opts.threads.max(1);
    let window = threads * opts.prefetch.max(1);
    // Clone per-thread twins up front so the replay pool owns its
    // machine models outright.
    let mut pool_twins: Vec<Twin> = (0..threads).map(|_| twin.clone()).collect();
    let stop = AtomicBool::new(false);
    let tasks: Mutex<VecDeque<(Arc<JobCtx>, usize)>> = Mutex::new(VecDeque::new());
    let task_ready = Condvar::new();
    let (tx, rx) = mpsc::channel::<WorkerEv>();

    std::thread::scope(|s| {
        // Reader: every inbound frame becomes an event; a read error
        // (EOF, garbage, a stalled frame) ends the connection.
        {
            let reader_tx = tx.clone();
            let stop = &stop;
            let patience = opts.patience;
            s.spawn(move || {
                let mut reader = reader;
                loop {
                    if stop.load(Ordering::Relaxed) {
                        return;
                    }
                    match read_msg_patient(&mut reader, patience) {
                        Ok(Some(m)) => {
                            if reader_tx.send(WorkerEv::In(m)).is_err() {
                                return;
                            }
                        }
                        Ok(None) => continue, // idle poll; stop-check and re-read
                        Err(e) => {
                            let _ = reader_tx.send(WorkerEv::ReadDead(e));
                            return;
                        }
                    }
                }
            });
        }
        // Replay pool: persistent arenas across groups *and* jobs on a
        // persistent fleet (armed lazily by `replay_group`, reset
        // between scenarios, trace cache warm throughout).
        for mut pool_twin in pool_twins.drain(..) {
            let pool_tx = tx.clone();
            let (tasks, task_ready, stop) = (&tasks, &task_ready, &stop);
            s.spawn(move || {
                let mut arena: Option<ReplayRig> = None;
                loop {
                    let (ctx, g) = {
                        let mut q = tasks.lock().expect("task queue poisoned");
                        loop {
                            if stop.load(Ordering::Relaxed) {
                                return;
                            }
                            if let Some(task) = q.pop_front() {
                                break task;
                            }
                            q = task_ready.wait(q).expect("task queue poisoned");
                        }
                    };
                    pool_twin.net.routing = ctx.routing;
                    let rows: Vec<(u64, ScenarioStats)> =
                        replay_group(&mut arena, &pool_twin, &ctx.scenarios, &ctx.groups[g])
                            .into_iter()
                            .map(|(i, stats)| (i as u64, stats))
                            .collect();
                    let done = WorkerEv::Done {
                        job: ctx.job,
                        group: g as u64,
                        rows,
                    };
                    if pool_tx.send(done).is_err() {
                        return;
                    }
                }
            });
        }

        // The protocol loop: sole owner of the write half.
        let out = (|| -> Result<usize> {
            let mut cur: Option<Arc<JobCtx>> = None;
            // Groups granted but not yet batched back, and credit
            // requested but not yet granted — their sum never exceeds
            // the prefetch window.
            let mut inflight = 0usize;
            let mut asked = 0usize;
            let mut acked = 0usize;
            let mut last_heard = Instant::now();
            loop {
                let ev = match rx.recv_timeout(opts.poll) {
                    Ok(ev) => ev,
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        ensure!(
                            last_heard.elapsed() <= opts.patience,
                            "worker {}: coordinator vanished ({:.1?} of silence, \
                             heartbeats expected)",
                            opts.id,
                            last_heard.elapsed()
                        );
                        continue;
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        bail!("worker {}: event stream ended", opts.id)
                    }
                };
                match ev {
                    WorkerEv::ReadDead(e) => {
                        return Err(e.context(format!(
                            "worker {}: coordinator connection failed",
                            opts.id
                        )))
                    }
                    WorkerEv::In(msg) => {
                        last_heard = Instant::now();
                        match msg {
                            Msg::Ping => write_msg(&mut writer, &Msg::Pong)?,
                            Msg::Spec { job, spec } => {
                                // A new job obsoletes anything still
                                // queued (in-flight replays of the old
                                // job finish and are dropped stale).
                                tasks.lock().expect("task queue poisoned").clear();
                                let ctx = Arc::new(JobCtx {
                                    job,
                                    routing: spec.routing,
                                    scenarios: spec.grid.scenarios(),
                                    groups: spec.grid.work_groups(spec.fork),
                                });
                                cur = Some(ctx);
                                inflight = 0;
                                asked = 0;
                                request_more(&mut writer, job, window, inflight, &mut asked)?;
                            }
                            Msg::Grant { job, groups } | Msg::Assign { job, groups } => {
                                // Grants for any grid but the one we
                                // were last told about are stale — a
                                // rejoin or a queue advance raced this
                                // frame. The coordinator re-dispatches.
                                let Some(ctx) = cur.as_ref().filter(|c| c.job == job) else {
                                    continue;
                                };
                                for &g in &groups {
                                    ensure!(
                                        (g as usize) < ctx.groups.len(),
                                        "worker {}: group {g} out of range (grid has {})",
                                        opts.id,
                                        ctx.groups.len()
                                    );
                                }
                                asked = asked.saturating_sub(groups.len());
                                inflight += groups.len();
                                {
                                    let mut q =
                                        tasks.lock().expect("task queue poisoned");
                                    for g in groups {
                                        q.push_back((Arc::clone(ctx), g as usize));
                                    }
                                }
                                task_ready.notify_all();
                            }
                            Msg::Shutdown => return Ok(acked),
                            other => bail!("worker {}: unexpected {other:?}", opts.id),
                        }
                    }
                    WorkerEv::Done { job, group, rows } => {
                        // A finished group of a stale job: its report
                        // moved on, drop the rows.
                        let Some(ctx) = cur.as_ref().filter(|c| c.job == job) else {
                            continue;
                        };
                        let job = ctx.job;
                        write_msg(&mut writer, &Msg::RowBatch { job, group, rows })?;
                        inflight = inflight.saturating_sub(1);
                        acked += 1;
                        if opts.die_after_groups.is_some_and(|n| acked >= n) {
                            // Simulated crash: drop the socket with
                            // groups still granted and unbatched.
                            return Ok(acked);
                        }
                        request_more(&mut writer, job, window, inflight, &mut asked)?;
                    }
                }
            }
        })();
        // Unblock the pool and the reader so the scope can join: the
        // condvar waiters check `stop`, the reader checks it each poll.
        stop.store(true, Ordering::Relaxed);
        tasks.lock().expect("task queue poisoned").clear();
        task_ready.notify_all();
        drop(rx);
        out
    })
}

/// Keep a worker on the fleet across coordinator restarts: connect,
/// serve, and — when the connection dies rather than being shut down
/// cleanly — back off and rejoin under the same identity until
/// `patience` runs out. Returns the groups acknowledged on the final
/// connection (earlier connections' work was re-dispatched anyway).
pub fn run_worker_resilient(
    twin: &mut Twin,
    addr: SocketAddr,
    opts: &WorkerOptions,
    patience: Duration,
) -> Result<usize> {
    let seed = fnv_seed(&opts.id);
    let deadline = Instant::now() + patience;
    let mut attempt = 0u32;
    loop {
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            bail!("worker {}: gave up rejoining {addr}", opts.id);
        }
        let stream = connect_retry_seeded(addr, remaining, seed)?;
        match run_worker(twin, stream, opts) {
            Ok(acked) => return Ok(acked),
            Err(e) => {
                let remaining = deadline.saturating_duration_since(Instant::now());
                if remaining.is_zero() {
                    return Err(e.context(format!(
                        "worker {}: gave up rejoining {addr}",
                        opts.id
                    )));
                }
                eprintln!("worker {}: connection lost, rejoining: {e:#}", opts.id);
                std::thread::sleep(backoff_delay(attempt, seed).min(remaining));
                attempt += 1;
            }
        }
    }
}

/// CLI entry point (`leonardo-twin work --connect HOST:PORT`): build a
/// LEONARDO twin, join the fleet, replay until shut down — rejoining
/// across coordinator restarts unless this worker is a chaos probe
/// (whose deterministic schedule is a one-shot experiment) or a
/// scripted crash (`--die-after`). `threads` sizes the replay-arena
/// pool, `prefetch` the per-thread credit window (`work --threads
/// --prefetch`).
pub fn work(
    connect: &str,
    die_after: Option<usize>,
    chaos: Option<u64>,
    threads: usize,
    prefetch: usize,
) -> Result<()> {
    let addr = parse_addr(connect)?;
    let mut twin = Twin::leonardo();
    let opts = WorkerOptions {
        die_after_groups: die_after,
        chaos,
        threads: threads.max(1),
        prefetch: prefetch.max(1),
        ..WorkerOptions::named(&format!("w{}", std::process::id()))
    };
    if let Some(seed) = chaos {
        let stream = connect_retry(addr, Duration::from_secs(30))?;
        // A chaos worker is *meant* to die mid-protocol; how it dies is
        // the experiment, not a failure of this process.
        match run_worker(&mut twin, stream, &opts) {
            Ok(acked) => eprintln!(
                "worker {} (chaos seed {seed}): replayed {acked} group(s)",
                opts.id
            ),
            Err(e) => eprintln!("worker {} (chaos seed {seed}): lost to chaos: {e:#}", opts.id),
        }
        return Ok(());
    }
    if die_after.is_some() {
        let stream = connect_retry(addr, Duration::from_secs(30))?;
        let acked = run_worker(&mut twin, stream, &opts)?;
        eprintln!("worker {}: crashed on schedule after {acked} group(s)", opts.id);
        return Ok(());
    }
    let acked = run_worker_resilient(&mut twin, addr, &opts, Duration::from_secs(30))?;
    eprintln!("worker {}: replayed {acked} group(s)", opts.id);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn parse_addr_accepts_host_port_and_rejects_garbage() {
        assert_eq!(
            parse_addr("127.0.0.1:7723").unwrap(),
            "127.0.0.1:7723".parse::<SocketAddr>().unwrap()
        );
        assert!(parse_addr("127.0.0.1").is_err(), "missing port");
        assert!(parse_addr("not an address").is_err());
        assert!(parse_addr("127.0.0.1:notaport").is_err());
        assert!(parse_addr("").is_err());
    }

    #[test]
    fn connect_retry_gives_up_with_context() {
        // Loopback port 1 refuses immediately (nothing may listen
        // there); patience zero turns that refusal into the error.
        let addr: SocketAddr = "127.0.0.1:1".parse().unwrap();
        let err = connect_retry(addr, Duration::from_millis(0)).unwrap_err();
        assert!(err.to_string().contains("no coordinator"));
    }

    #[test]
    fn backoff_is_deterministic_grows_and_caps_at_a_second() {
        for attempt in 0..16 {
            for seed in 0..8 {
                let d = backoff_delay(attempt, seed);
                assert_eq!(d, backoff_delay(attempt, seed), "same inputs, same delay");
                assert!(d <= Duration::from_millis(1_000), "cap breached: {d:?}");
                assert!(d >= Duration::from_millis(5), "degenerate delay: {d:?}");
            }
        }
        // Early attempts are short, late attempts saturate the cap's
        // window rather than growing without bound.
        assert!(backoff_delay(0, 3) <= Duration::from_millis(10));
        assert!(backoff_delay(9, 3) >= Duration::from_millis(500));
        // Jitter actually varies with the seed somewhere.
        assert!(
            (0..32).any(|s| backoff_delay(4, s) != backoff_delay(4, s + 32)),
            "every seed collapsed to one delay"
        );
    }

    #[test]
    fn a_silent_coordinator_is_a_clear_error_not_a_hang() {
        // A "coordinator" that accepts and then says nothing: the
        // worker must bail with a vanished error once its patience —
        // not some unbounded socket wait — is exhausted.
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let hold = std::thread::spawn(move || listener.accept().map(|(s, _)| s));
        let stream = TcpStream::connect(addr).unwrap();
        let mut twin = Twin::leonardo();
        let opts = WorkerOptions {
            poll: Duration::from_millis(10),
            patience: Duration::from_millis(150),
            ..WorkerOptions::named("w-abandoned")
        };
        let t0 = Instant::now();
        let err = run_worker(&mut twin, stream, &opts).unwrap_err();
        assert!(
            format!("{err:#}").contains("vanished"),
            "unexpected error: {err:#}"
        );
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "took {:?} to notice a silent coordinator",
            t0.elapsed()
        );
        drop(hold.join().unwrap());
    }
}
