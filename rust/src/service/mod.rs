//! Distributed sweep service: a coordinator + worker fleet that scales
//! the campaign engine past one process.
//!
//! LEONARDO itself is operated as a shared service — login/management
//! nodes front a fleet that work is dispatched to (§2), where
//! component failure is routine and the machine must stay productive
//! through it — and this module reproduces that operations model at
//! the campaign layer:
//!
//! * [`shard`] — the consistent-hash ring: the whole assignment in
//!   static dispatch mode, the deterministic tie-break among credited
//!   workers in adaptive mode;
//! * [`messages`] — the hand-rolled length-prefixed JSON protocol on
//!   `std::net` TCP (offline-hermetic: no serde, no async runtime),
//!   including the timeout-tolerant patient reader and the batched
//!   `Next`/`Grant`/`RowBatch` credit flow;
//! * [`worker`] — one connection driving a pool of replay threads
//!   (`work --threads`), each with a persistent
//!   [`crate::campaign::ReplayRig`] arena, pulling group credit and
//!   batching each finished group into a single `RowBatch` frame,
//!   answering heartbeats and rejoining across coordinator restarts
//!   (CLI `work`);
//! * [`coordinator`] — listener, adaptive LPT ready-queue (cost hints
//!   refined by per-class service times), ownership table, the bounded
//!   multi-grid job queue, heartbeat/per-class-deadline liveness, and
//!   the grid-index slot merge (CLI `serve`), byte-identical to the
//!   single-process engines for any worker count, thread count, join
//!   order, prefetch depth, or failure schedule;
//! * [`client`] — submit a grid to a running coordinator and collect
//!   its report, or drain the service (CLI `submit`);
//! * [`chaos`] — the seeded wire-fault harness
//!   ([`chaos::FaultyTransport`]) that the robustness suite and the
//!   CI chaos step drive the service with.
//!
//! The high-level entry points are [`Twin::sweep_distributed`]
//! (in-process fleet), [`coordinator::serve`] /
//! [`coordinator::serve_service`] / [`worker::work`] (multi-process
//! fleet over TCP), and [`client::submit`] / [`client::drain`]
//! (jobs against a persistent fleet).
//!
//! [`Twin::sweep_distributed`]: crate::coordinator::Twin::sweep_distributed

pub mod chaos;
pub mod client;
pub mod coordinator;
pub mod messages;
pub mod shard;
pub mod worker;

pub use chaos::{FaultPlan, FaultyTransport, WireFault};
pub use client::{drain, submit};
pub use coordinator::{
    run_distributed, run_distributed_cfg, run_fleet, serve, serve_listener, serve_service,
    CoordinatorConfig, DispatchMode, ServiceStats,
};
pub use messages::{Msg, SweepSpec};
pub use shard::{HashRing, DEFAULT_REPLICAS};
pub use worker::{
    backoff_delay, connect_retry, connect_retry_seeded, parse_addr, run_worker,
    run_worker_io, run_worker_resilient, work, WorkerOptions,
};
