//! Distributed sweep service: a coordinator + worker fleet that scales
//! the campaign engine past one process.
//!
//! LEONARDO itself is operated as a shared service — login/management
//! nodes front a fleet that work is dispatched to (§2) — and this
//! module reproduces that operations model at the campaign layer:
//!
//! * [`shard`] — the consistent-hash ring giving every scenario group
//!   a stable owner that survives worker join/leave with minimal
//!   reassignment;
//! * [`messages`] — the hand-rolled length-prefixed JSON protocol on
//!   `std::net` TCP (offline-hermetic: no serde, no async runtime);
//! * [`worker`] — one connection replaying assigned groups on a
//!   persistent [`crate::campaign::ReplayRig`] arena (CLI `work`);
//! * [`coordinator`] — listener, ring, ownership table and the
//!   grid-index slot merge (CLI `serve`), byte-identical to the
//!   single-process engines for any worker count.
//!
//! The high-level entry points are [`Twin::sweep_distributed`]
//! (in-process fleet) and [`coordinator::serve`] /
//! [`worker::work`] (multi-process fleet over TCP).
//!
//! [`Twin::sweep_distributed`]: crate::coordinator::Twin::sweep_distributed

pub mod coordinator;
pub mod messages;
pub mod shard;
pub mod worker;

pub use coordinator::{run_distributed, serve, CoordinatorConfig, ServiceStats};
pub use messages::{Msg, SweepSpec};
pub use shard::{HashRing, DEFAULT_REPLICAS};
pub use worker::{parse_addr, run_worker, work, WorkerOptions};
