//! GPU accelerator specification models (paper §2.1.1, Table 2).
//!
//! Encodes the three devices the paper compares — the *custom* Da Vinci
//! A100 variant installed in LEONARDO (124 SM), the standard SXM A100
//! (108 SM) and the Volta V100 (80 SM) — and derives every peak-rate row
//! of Table 2 from first principles (SM count x per-SM issue width x
//! clock), so the table is *computed*, not transcribed.



/// Numerical formats of Table 2 (plus the sparse variants of §2.1.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    /// IEEE double precision on the CUDA FP64 cores.
    Fp64,
    /// IEEE single precision on the CUDA FP32 cores.
    Fp32,
    /// Double precision on the tensor cores (DMMA) — Ampere only.
    Fp64TensorCore,
    /// TensorFloat-32: 8-bit range / 10-bit mantissa, the transparent
    /// default for AI training on Ampere.
    Tf32TensorCore,
    /// FP16 tensor-core math (also covers BF16: same throughput class).
    Fp16TensorCore,
    /// INT8 tensor-core ops.
    Int8TensorCore,
    /// INT4 tensor-core ops.
    Int4TensorCore,
}

/// GPU micro-architecture generation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GpuArch {
    Ampere,
    Volta,
}

/// Static description of a GPU device.
#[derive(Debug, Clone)]
pub struct GpuSpec {
    pub name: &'static str,
    pub arch: GpuArch,
    /// Streaming multiprocessors enabled on this part.
    pub sm_count: u32,
    /// Boost clock used for peak-rate arithmetic, MHz.
    pub boost_clock_mhz: u32,
    /// L2 cache, MiB.
    pub l2_cache_mib: u32,
    /// On-package HBM capacity, GiB.
    pub memory_gib: u32,
    /// HBM bandwidth, GB/s.
    pub memory_bw_gbs: f64,
    /// Board power limit, W.
    pub tdp_w: f64,
    /// Idle power draw, W (used by the energy model).
    pub idle_w: f64,
}

impl GpuSpec {
    /// The custom "Da Vinci" A100 installed in LEONARDO: 124 of 128 SMs
    /// (a 97% implementation of the full GA100), 64 GiB HBM2e, 440 W.
    pub fn a100_custom() -> Self {
        GpuSpec {
            name: "Ampere A100 (custom)",
            arch: GpuArch::Ampere,
            sm_count: 124,
            boost_clock_mhz: 1395,
            l2_cache_mib: 32,
            memory_gib: 64,
            memory_bw_gbs: 1640.0,
            tdp_w: 440.0,
            idle_w: 55.0,
        }
    }

    /// The standard SXM4 A100 (108 SM, 40 GiB) for reference.
    pub fn a100_standard() -> Self {
        GpuSpec {
            name: "Ampere A100",
            arch: GpuArch::Ampere,
            sm_count: 108,
            boost_clock_mhz: 1410,
            l2_cache_mib: 40,
            memory_gib: 40,
            memory_bw_gbs: 1555.0,
            tdp_w: 400.0,
            idle_w: 50.0,
        }
    }

    /// The Volta V100 (Marconi100's GPU, the Fig 5 comparator).
    pub fn v100() -> Self {
        GpuSpec {
            name: "Volta V100",
            arch: GpuArch::Volta,
            sm_count: 80,
            boost_clock_mhz: 1530,
            l2_cache_mib: 6,
            memory_gib: 16,
            memory_bw_gbs: 900.0,
            tdp_w: 300.0,
            idle_w: 40.0,
        }
    }

    /// CUDA FP64 cores (32 per SM on both Volta and Ampere).
    pub fn fp64_cores(&self) -> u32 {
        self.sm_count * 32
    }

    /// CUDA FP32 cores (64 per SM).
    pub fn fp32_cores(&self) -> u32 {
        self.sm_count * 64
    }

    /// Tensor cores: 4 per SM on Ampere (3rd gen), 8 per SM on Volta.
    pub fn tensor_cores(&self) -> u32 {
        match self.arch {
            GpuArch::Ampere => self.sm_count * 4,
            GpuArch::Volta => self.sm_count * 8,
        }
    }

    /// Peak rate in FLOPS (or OPS for integer formats) for `p`.
    ///
    /// Derivation (per clock, per SM): FP64 32 cores x 2 (FMA) = 64;
    /// FP32 128; Ampere tensor cores: FP64 DMMA 128, TF32 1024,
    /// FP16/BF16 2048, INT8 4096, INT4 8192. Volta tensor cores only
    /// support FP16 (1024/SM/clk); its TC FP64/TF32/INT rows are `None`
    /// (Table 2 prints "n.a.").
    pub fn peak_flops(&self, p: Precision) -> Option<f64> {
        let clk = self.boost_clock_mhz as f64 * 1e6;
        let sm = self.sm_count as f64;
        let per_sm_per_clk: f64 = match (self.arch, p) {
            (_, Precision::Fp64) => 64.0,
            (_, Precision::Fp32) => 128.0,
            (GpuArch::Ampere, Precision::Fp64TensorCore) => 128.0,
            (GpuArch::Ampere, Precision::Tf32TensorCore) => 1024.0,
            (GpuArch::Ampere, Precision::Fp16TensorCore) => 2048.0,
            (GpuArch::Ampere, Precision::Int8TensorCore) => 4096.0,
            (GpuArch::Ampere, Precision::Int4TensorCore) => 8192.0,
            (GpuArch::Volta, Precision::Fp16TensorCore) => 1024.0,
            (GpuArch::Volta, _) => return None,
        };
        Some(sm * per_sm_per_clk * clk)
    }

    /// Peak with 2:4 structural sparsity (§2.1.1): a clean 2x on the
    /// tensor-core formats of Ampere, unavailable elsewhere.
    pub fn peak_flops_sparse(&self, p: Precision) -> Option<f64> {
        if self.arch != GpuArch::Ampere {
            return None;
        }
        match p {
            Precision::Fp64 | Precision::Fp32 | Precision::Fp64TensorCore => None,
            _ => self.peak_flops(p).map(|f| 2.0 * f),
        }
    }

    /// HBM stacks: the custom A100 carries 4 x 16 GiB HBM2e stacks, each
    /// driven by two 512-bit controllers at 3200 MT/s (§2.1.2).
    pub fn hbm_stacks(&self) -> u32 {
        self.memory_gib / 16
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tflops(v: Option<f64>) -> f64 {
        v.unwrap() / 1e12
    }

    /// Every numeric cell of Table 2, derived, within rounding tolerance.
    #[test]
    fn table2_a100_custom() {
        let g = GpuSpec::a100_custom();
        assert!((tflops(g.peak_flops(Precision::Fp64)) - 11.2).abs() < 0.2);
        assert!((tflops(g.peak_flops(Precision::Fp32)) - 22.4).abs() < 0.4);
        assert!(
            (tflops(g.peak_flops(Precision::Fp64TensorCore)) - 22.4).abs() < 0.4
        );
        assert!(
            (tflops(g.peak_flops(Precision::Tf32TensorCore)) - 179.0).abs() < 3.0
        );
        assert!(
            (tflops(g.peak_flops(Precision::Fp16TensorCore)) - 358.0).abs() < 6.0
        );
        assert!(
            (tflops(g.peak_flops(Precision::Int8TensorCore)) - 716.0).abs() < 12.0
        );
        assert!(
            (tflops(g.peak_flops(Precision::Int4TensorCore)) - 1432.0).abs() < 24.0
        );
    }

    #[test]
    fn table2_a100_standard() {
        let g = GpuSpec::a100_standard();
        assert!((tflops(g.peak_flops(Precision::Fp64)) - 9.7).abs() < 0.2);
        assert!((tflops(g.peak_flops(Precision::Fp32)) - 19.5).abs() < 0.3);
        assert!(
            (tflops(g.peak_flops(Precision::Tf32TensorCore)) - 156.0).abs() < 3.0
        );
        assert!(
            (tflops(g.peak_flops(Precision::Fp16TensorCore)) - 312.0).abs() < 5.0
        );
        assert!(
            (tflops(g.peak_flops(Precision::Int8TensorCore)) - 624.0).abs() < 10.0
        );
    }

    #[test]
    fn table2_v100() {
        let g = GpuSpec::v100();
        assert!((tflops(g.peak_flops(Precision::Fp64)) - 7.8).abs() < 0.2);
        assert!((tflops(g.peak_flops(Precision::Fp32)) - 15.7).abs() < 0.3);
        assert!(g.peak_flops(Precision::Fp64TensorCore).is_none());
        assert!(g.peak_flops(Precision::Tf32TensorCore).is_none());
        assert!(g.peak_flops(Precision::Int8TensorCore).is_none());
        // V100 FP16 TC: 125 TFLOPS on the datasheet.
        assert!(
            (tflops(g.peak_flops(Precision::Fp16TensorCore)) - 125.0).abs() < 3.0
        );
    }

    #[test]
    fn table2_core_counts() {
        let c = GpuSpec::a100_custom();
        assert_eq!(c.fp64_cores(), 3968);
        assert_eq!(c.fp32_cores(), 7936);
        assert_eq!(c.tensor_cores(), 496);
        let s = GpuSpec::a100_standard();
        assert_eq!(s.fp64_cores(), 3456);
        assert_eq!(s.fp32_cores(), 6912);
        assert_eq!(s.tensor_cores(), 432);
        let v = GpuSpec::v100();
        assert_eq!(v.fp64_cores(), 2560);
        assert_eq!(v.fp32_cores(), 5120);
        assert_eq!(v.tensor_cores(), 640);
    }

    #[test]
    fn custom_is_97_percent_of_full_ga100() {
        let g = GpuSpec::a100_custom();
        assert!((g.sm_count as f64 / 128.0 - 0.97).abs() < 0.01);
    }

    #[test]
    fn structural_sparsity_doubles_tc_rates() {
        let g = GpuSpec::a100_custom();
        let dense = g.peak_flops(Precision::Int8TensorCore).unwrap();
        let sparse = g.peak_flops_sparse(Precision::Int8TensorCore).unwrap();
        assert_eq!(sparse, 2.0 * dense);
        assert!(g.peak_flops_sparse(Precision::Fp64).is_none());
        assert!(GpuSpec::v100()
            .peak_flops_sparse(Precision::Fp16TensorCore)
            .is_none());
    }

    #[test]
    fn hbm_geometry() {
        let g = GpuSpec::a100_custom();
        assert_eq!(g.hbm_stacks(), 4);
        // 4 stacks x 2 controllers x 512 bit x 3200 MT/s = 1638 GB/s (§2.1.2)
        let bw: f64 = 4.0 * 2.0 * 512.0 / 8.0 * 3.2e9 / 1e9;
        assert!((bw - 1638.4).abs() < 1.0);
        assert!((g.memory_bw_gbs - bw).abs() < 5.0);
    }

    #[test]
    fn ampere_vs_volta_improvements() {
        // §2.1.1: +24% FP and +73% memory bandwidth minimum A100 vs V100.
        let a = GpuSpec::a100_standard();
        let v = GpuSpec::v100();
        let fp = a.peak_flops(Precision::Fp64).unwrap()
            / v.peak_flops(Precision::Fp64).unwrap();
        assert!(fp > 1.20, "fp64 speedup {fp}");
        let bw = a.memory_bw_gbs / v.memory_bw_gbs;
        assert!(bw > 1.70, "bw speedup {bw}");
    }
}
