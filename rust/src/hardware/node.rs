//! Node/blade models: the Da Vinci GPU blade (§2.1.2, Fig 2-3), the DC
//! blade and the Marconi100 comparator node, with intra-node fabric
//! (PCIe Gen4 + NVLink 3.0) bandwidth arithmetic.



use super::cpu::CpuSpec;
use super::gpu::{GpuSpec, Precision};

/// Intra-node link technologies (Fig 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IntraLink {
    /// One x16 PCIe Gen 4.0 bundle: 32 GB/s per direction.
    PcieGen4x16,
    /// NVLink 3.0: 200 GB/s bidirectional per GPU pair.
    NvLink3,
}

impl IntraLink {
    /// Usable bandwidth of one link, GB/s.
    pub fn bandwidth_gbs(self) -> f64 {
        match self {
            IntraLink::PcieGen4x16 => 32.0,
            IntraLink::NvLink3 => 200.0,
        }
    }
}

/// A compute node specification.
#[derive(Debug, Clone)]
pub struct NodeSpec {
    pub name: &'static str,
    pub cpu: CpuSpec,
    pub cpu_sockets: u32,
    pub gpu: Option<GpuSpec>,
    pub gpus: u32,
    /// InfiniBand rails out of the node and per-rail Gbps.
    pub nic_rails: u32,
    pub rail_gbps: f64,
    /// Per-message NIC latency, ns (§2.2: ConnectX-6 is 600 ns).
    pub nic_latency_ns: f64,
    /// GPU->NIC staging bandwidth when GPUDirect RDMA is unavailable,
    /// GB/s. `None` = GPUDirect (CX6 on LEONARDO, §2.2/§2.3): device
    /// buffers go straight to the wire. `Some(bw)` = halos bounce through
    /// host memory at `bw` (V100-era PCIe Gen3 staging on Marconi100).
    pub host_staging_gbs: Option<f64>,
}

impl NodeSpec {
    /// LEONARDO Booster "Da Vinci" blade (BullSequana X2135): one Ice Lake
    /// socket, four custom A100s, 2 dual-port HDR100 NICs = 4 x 100 Gbps
    /// rails (400 Gbps aggregated).
    pub fn davinci() -> Self {
        NodeSpec {
            name: "Da Vinci (BullSequana X2135)",
            cpu: CpuSpec::icelake_8358(),
            cpu_sockets: 1,
            gpu: Some(GpuSpec::a100_custom()),
            gpus: 4,
            nic_rails: 4,
            rail_gbps: 100.0,
            nic_latency_ns: 600.0,
            host_staging_gbs: None,
        }
    }

    /// Data-Centric node (1/3 of a BullSequana X2140 blade): two Sapphire
    /// Rapids sockets, one HDR100 link.
    pub fn dc_node() -> Self {
        NodeSpec {
            name: "DC (BullSequana X2140)",
            cpu: CpuSpec::sapphire_rapids_8480p(),
            cpu_sockets: 2,
            gpu: None,
            gpus: 0,
            nic_rails: 1,
            rail_gbps: 100.0,
            nic_latency_ns: 600.0,
            host_staging_gbs: None,
        }
    }

    /// Marconi100 node (the Fig 5 comparator): POWER9-class host modelled
    /// with the Ice Lake spec (host is irrelevant to the GPU-bound LBM),
    /// 4 x V100, 2 x 100 Gbps EDR rails.
    pub fn marconi100_node() -> Self {
        NodeSpec {
            name: "Marconi100 (IC922-class)",
            cpu: CpuSpec::icelake_8358(),
            cpu_sockets: 2,
            gpu: Some(GpuSpec::v100()),
            gpus: 4,
            nic_rails: 2,
            rail_gbps: 100.0,
            nic_latency_ns: 700.0,
            host_staging_gbs: Some(10.0), // PCIe Gen3 host bounce buffers
        }
    }

    /// Node peak FLOPS at precision `p` (GPUs + host AVX-512).
    pub fn peak_flops(&self, p: Precision) -> f64 {
        let gpu = self
            .gpu
            .as_ref()
            .and_then(|g| g.peak_flops(p))
            .unwrap_or(0.0)
            * self.gpus as f64;
        let cpu = if p == Precision::Fp64 {
            self.cpu.peak_fp64_flops() * self.cpu_sockets as f64
        } else {
            0.0
        };
        gpu + cpu
    }

    /// Aggregate GPU HBM bandwidth, GB/s (§2.1.2: 6.5 TB/s per blade).
    pub fn gpu_memory_bw_gbs(&self) -> f64 {
        self.gpu.as_ref().map_or(0.0, |g| g.memory_bw_gbs) * self.gpus as f64
    }

    /// Aggregate GPU memory capacity, GiB (§2.1.2: 320 GB per blade...
    /// the paper text says 320, i.e. 4 x 64 = 256 GiB of HBM2e plus 64 GiB
    /// of spill — we expose the HBM figure).
    pub fn gpu_memory_gib(&self) -> u32 {
        self.gpu.as_ref().map_or(0, |g| g.memory_gib) * self.gpus
    }

    /// CPU->GPU PCIe bandwidth: one x16 Gen4 bundle per GPU (Fig 3).
    pub fn pcie_bw_per_gpu_gbs(&self) -> f64 {
        IntraLink::PcieGen4x16.bandwidth_gbs()
    }

    /// Total CPU PCIe bandwidth across the 64 lanes (Fig 3: 128 GB/s).
    pub fn pcie_total_bw_gbs(&self) -> f64 {
        self.gpus as f64 * self.pcie_bw_per_gpu_gbs()
    }

    /// All-pairs NVLink bisection: 200 GB/s per pair, 600 GB/s per GPU
    /// total across its 3 peers (Fig 3).
    pub fn nvlink_bw_per_gpu_gbs(&self) -> f64 {
        if self.gpus < 2 || self.gpu.is_none() {
            return 0.0;
        }
        IntraLink::NvLink3.bandwidth_gbs() * (self.gpus - 1).min(3) as f64
    }

    /// Injection bandwidth into the fabric, Gbps.
    pub fn injection_gbps(&self) -> f64 {
        self.nic_rails as f64 * self.rail_gbps
    }

    /// Node DRAM, GiB.
    pub fn dram_gib(&self) -> u32 {
        self.cpu.dram_gib * self.cpu_sockets
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn davinci_peak_is_about_89_tflops_fp64_tc() {
        // §1 quotes "78 teraFLOPS" per node — that is 4 x 19.5 (standard
        // A100 FP64 TC); with the custom part it is 4 x 22.4 ~ 89.6 + CPU.
        let n = NodeSpec::davinci();
        let peak = n.peak_flops(Precision::Fp64TensorCore) / 1e12;
        assert!((peak - 89.6).abs() < 1.5, "{peak}");
        let std = 4.0
            * GpuSpec::a100_standard()
                .peak_flops(Precision::Fp64TensorCore)
                .unwrap()
            / 1e12;
        assert!((std - 78.0).abs() < 1.0, "{std}");
    }

    #[test]
    fn davinci_hbm_aggregate_is_6_5_tbs() {
        let n = NodeSpec::davinci();
        assert!((n.gpu_memory_bw_gbs() / 1000.0 - 6.56).abs() < 0.1);
        assert_eq!(n.gpu_memory_gib(), 256);
    }

    #[test]
    fn davinci_pcie_budget_matches_fig3() {
        let n = NodeSpec::davinci();
        assert_eq!(n.pcie_bw_per_gpu_gbs(), 32.0);
        assert_eq!(n.pcie_total_bw_gbs(), 128.0);
    }

    #[test]
    fn davinci_nvlink_600_gbs_per_gpu() {
        let n = NodeSpec::davinci();
        assert_eq!(n.nvlink_bw_per_gpu_gbs(), 600.0);
    }

    #[test]
    fn davinci_injection_400_gbps() {
        let n = NodeSpec::davinci();
        assert_eq!(n.injection_gbps(), 400.0);
        assert_eq!(n.dram_gib(), 512);
    }

    #[test]
    fn dc_node_single_rail() {
        let n = NodeSpec::dc_node();
        assert_eq!(n.injection_gbps(), 100.0);
        assert_eq!(n.gpus, 0);
        assert_eq!(n.gpu_memory_bw_gbs(), 0.0);
        assert_eq!(n.dram_gib(), 512);
    }

    #[test]
    fn marconi_node_is_v100_based() {
        let n = NodeSpec::marconi100_node();
        assert_eq!(n.gpu.as_ref().unwrap().name, "Volta V100");
        assert_eq!(n.nvlink_bw_per_gpu_gbs(), 600.0);
    }
}
