//! Hardware specification models for every component the paper names:
//! GPU devices (§2.1.1, Table 2), host CPUs (§2.1.2), and node/blade
//! assemblies with their intra-node fabric (Fig 3).
//!
//! All peak rates are *derived* from micro-architectural parameters
//! (SM/core counts, issue widths, clocks) and unit-tested against the
//! paper's tables, so a config change propagates consistently through
//! the performance and power models.

pub mod cpu;
pub mod gpu;
pub mod node;

pub use cpu::CpuSpec;
pub use gpu::{GpuArch, GpuSpec, Precision};
pub use node::{IntraLink, NodeSpec};
