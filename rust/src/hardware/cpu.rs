//! Host CPU specification models (paper §2.1.2, §2, Appendix B).



/// Static description of a CPU socket.
#[derive(Debug, Clone)]
pub struct CpuSpec {
    pub name: &'static str,
    pub cores: u32,
    /// Nominal all-core frequency, GHz.
    pub clock_ghz: f64,
    /// AVX-512 FMA units per core (2 on Ice Lake Platinum / SPR).
    pub avx512_units: u32,
    /// Last-level cache, MiB.
    pub llc_mib: u32,
    /// Memory channels per socket.
    pub memory_channels: u32,
    /// Per-channel bandwidth, GB/s.
    pub channel_bw_gbs: f64,
    /// Installed DRAM per socket, GiB.
    pub dram_gib: u32,
    /// Socket TDP, W.
    pub tdp_w: f64,
    /// Idle draw, W.
    pub idle_w: f64,
}

impl CpuSpec {
    /// The Booster host: Intel Xeon Platinum 8358 "Ice Lake", 32 cores,
    /// 2.6 GHz, 48 MiB LLC, 8 x DDR4-3200 channels (25 GB/s each, 200 GB/s
    /// total), 8 x 64 GiB DIMMs (§2.1.2).
    pub fn icelake_8358() -> Self {
        CpuSpec {
            name: "Xeon Platinum 8358 (Ice Lake)",
            cores: 32,
            clock_ghz: 2.6,
            avx512_units: 2,
            llc_mib: 48,
            memory_channels: 8,
            channel_bw_gbs: 25.0,
            dram_gib: 512,
            tdp_w: 250.0,
            idle_w: 45.0,
        }
    }

    /// The Data-Centric partition socket: Xeon Platinum 8480+ "Sapphire
    /// Rapids", 56 cores, 2.0 GHz, DDR5-4800 (§1, Appendix B).
    pub fn sapphire_rapids_8480p() -> Self {
        CpuSpec {
            name: "Xeon Platinum 8480+ (Sapphire Rapids)",
            cores: 56,
            clock_ghz: 2.0,
            avx512_units: 2,
            llc_mib: 105,
            memory_channels: 8,
            channel_bw_gbs: 38.4,
            dram_gib: 256, // 16 x 32 GiB shared across 2 sockets = 512/node
            tdp_w: 350.0,
            idle_w: 60.0,
        }
    }

    /// Service-partition socket: AMD EPYC 7H12 "Rome", 64 cores (§2.4).
    pub fn epyc_rome_7h12() -> Self {
        CpuSpec {
            name: "EPYC 7H12 (Rome)",
            cores: 64,
            clock_ghz: 2.6,
            avx512_units: 0, // AVX2-class, modelled as 0 AVX-512 units
            llc_mib: 256,
            memory_channels: 8,
            channel_bw_gbs: 25.6,
            dram_gib: 512,
            tdp_w: 280.0,
            idle_w: 65.0,
        }
    }

    /// Double-precision FLOP per core per clock cycle.
    ///
    /// Each AVX-512 unit retires one FMA on 8 f64 lanes per cycle:
    /// 2 units x 8 lanes x 2 flops = 32 flop/cycle/core. The paper's
    /// "1024 operations per clock cycle" is the per-socket figure
    /// (32 cores x 32): we compute, not transcribe.
    pub fn fp64_flop_per_core_clk(&self) -> f64 {
        self.avx512_units as f64 * 8.0 * 2.0
    }

    /// Peak double-precision FLOPS for the whole socket.
    pub fn peak_fp64_flops(&self) -> f64 {
        self.cores as f64 * self.fp64_flop_per_core_clk() * self.clock_ghz * 1e9
    }

    /// Aggregate DRAM bandwidth, GB/s.
    pub fn memory_bw_gbs(&self) -> f64 {
        self.memory_channels as f64 * self.channel_bw_gbs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn icelake_ops_per_clock_match_paper() {
        let c = CpuSpec::icelake_8358();
        // §2.1.2: "1024 operations per clock cycle" across the socket.
        let socket_ops = c.cores as f64 * c.fp64_flop_per_core_clk();
        assert_eq!(socket_ops, 1024.0);
    }

    #[test]
    fn icelake_peak_is_about_2_6_tflops() {
        // §2.1.2 quotes 2.6 TFLOPS (the text says "per core", an obvious
        // slip: 1024 op/clk x 2.6 GHz = 2.66 TFLOPS per *socket*).
        let c = CpuSpec::icelake_8358();
        assert!((c.peak_fp64_flops() / 1e12 - 2.66).abs() < 0.05);
    }

    #[test]
    fn icelake_memory_system() {
        let c = CpuSpec::icelake_8358();
        assert_eq!(c.memory_bw_gbs(), 200.0); // 8 x 25 GB/s (§2.1.2)
        assert_eq!(c.dram_gib, 512); // 8 x 64 GiB DIMMs
    }

    #[test]
    fn dc_node_core_count() {
        // Appendix B: 1536 nodes x 2 x 56 cores = 172032 cores.
        let c = CpuSpec::sapphire_rapids_8480p();
        assert_eq!(1536 * 2 * c.cores, 172_032);
    }

    #[test]
    fn rome_has_64_cores() {
        assert_eq!(CpuSpec::epyc_rome_7h12().cores, 64);
    }
}
