//! Vendored stand-in for the `anyhow` crate (the offline build has no
//! crates.io access, mirroring the in-crate JSON parser and bench
//! harness). Implements the API subset the twin uses: [`Error`],
//! [`Result`], the `anyhow!` / `bail!` / `ensure!` macros and the
//! [`Context`] extension trait for `Result` and `Option`.
//!
//! Behavioural notes kept compatible with the real crate:
//! * `Error` deliberately does **not** implement `std::error::Error`, so
//!   the blanket `From<E: std::error::Error>` impl can coexist with the
//!   reflexive `From<Error>` the `?` operator needs;
//! * `{err:#}` (alternate display) renders the full cause chain
//!   `outer: inner: ...`, which the CLI and tests rely on.

use std::error::Error as StdError;
use std::fmt;

/// An error type carrying a message and an optional cause chain.
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

/// `Result` alias defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            msg: message.to_string(),
            cause: None,
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error {
            msg: context.to_string(),
            cause: Some(Box::new(self)),
        }
    }

    /// The outermost message (no cause chain).
    pub fn root_message(&self) -> &str {
        &self.msg
    }

    /// Messages from outermost to innermost cause.
    pub fn chain(&self) -> Vec<&str> {
        let mut out = vec![self.msg.as_str()];
        let mut cause = &self.cause;
        while let Some(e) = cause {
            out.push(e.msg.as_str());
            cause = &e.cause;
        }
        out
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // Flatten the std source chain into nested Errors.
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut it = msgs.into_iter().rev();
        let mut err = Error::msg(it.next().expect("at least one message"));
        for m in it {
            err = err.context(m);
        }
        err
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cause = &self.cause;
            while let Some(e) = cause {
                write!(f, ": {}", e.msg)?;
                cause = &e.cause;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if let Some(first) = &self.cause {
            write!(f, "\n\nCaused by:")?;
            let mut cause = Some(first);
            while let Some(e) = cause {
                write!(f, "\n    {}", e.msg)?;
                cause = e.cause.as_ref();
            }
        }
        Ok(())
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an error built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn context_chain_renders_in_alternate_display() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "reading manifest — run `make artifacts` first".to_string())
            .unwrap_err();
        let full = format!("{e:#}");
        assert!(full.contains("make artifacts"), "{full}");
        assert!(full.contains("no such file"), "{full}");
        // Plain display shows only the outer message.
        assert!(!format!("{e}").contains("no such file"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn macros_build_errors() {
        fn f(v: u32) -> Result<u32> {
            ensure!(v < 10, "v too big: {v}");
            if v == 7 {
                bail!("unlucky {v}");
            }
            Err(anyhow!("fallthrough {}", v))
        }
        assert_eq!(f(12).unwrap_err().root_message(), "v too big: 12");
        assert_eq!(f(7).unwrap_err().root_message(), "unlucky 7");
        assert_eq!(f(1).unwrap_err().root_message(), "fallthrough 1");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.root_message(), "missing value");
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e = Error::msg("inner").context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer") && dbg.contains("Caused by") && dbg.contains("inner"));
    }
}
