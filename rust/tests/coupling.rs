//! Acceptance suite for runtime coupling (ISSUE 3): with `Coupling`
//! off, behavior is bit-for-bit the oracle engines (pinned by the
//! `sim_scheduler` suites); with it on,
//!
//! * (a) a comm-bound multi-cell job is measurably stretched by a
//!   co-scheduled multi-cell neighbour — and un-stretches (its `End`
//!   re-timed earlier) when the neighbour leaves mid-flight;
//! * (b) a `CapChange` mid-job shifts a running job's `End`;
//! * (c) coupled sweep reports are identical for 1, 2 and 8 worker
//!   threads.

use leonardo_twin::campaign::{run_sweep, run_sweep_streaming, SweepGrid};
use leonardo_twin::config::{CellConfig, CellKind, MachineConfig, RackGroup};
use leonardo_twin::coordinator::Twin;
use leonardo_twin::hardware::NodeSpec;
use leonardo_twin::scheduler::{CheckpointPolicy, Coupling, Job, Partition, PolicyKind, PowerCap, Scheduler};
use leonardo_twin::sim::{Component, Event, ScheduledEvent};
use leonardo_twin::topology::Routing;
use leonardo_twin::workloads::TraceGen;

fn job(id: u64, nodes: u32, secs: f64, submit: f64, comm: f64) -> Job {
    Job {
        id,
        partition: Partition::Booster,
        nodes,
        est_seconds: secs,
        run_seconds: secs,
        submit_time: submit,
        boundness: 1.0,
        comm_fraction: comm,
        checkpoint: CheckpointPolicy::None,
    }
}

fn coupled_sched() -> Scheduler {
    Scheduler::with_coupling(&MachineConfig::leonardo(), Coupling::full())
}

/// Counts Retime events on the shared stream.
#[derive(Default)]
struct RetimeProbe {
    retimes: u32,
}

impl Component for RetimeProbe {
    fn on_event(&mut self, _now: f64, ev: &Event, _out: &mut Vec<ScheduledEvent>) {
        if let Event::Retime { .. } = ev {
            self.retimes += 1;
        }
    }
}

/// The neighbour: fills all but 360 Booster nodes, so the probe job is
/// forced into the leftover cells and shares at least one cell with it.
fn neighbour(secs: f64) -> Job {
    job(1, 3456 - 360, secs, 0.0, 0.0)
}

/// (a) A comm-bound multi-cell job stretches under a co-scheduled
/// multi-cell neighbour; a compute-bound twin in the same spot does
/// not.
#[test]
fn comm_bound_job_stretches_under_multi_cell_neighbour() {
    // Comm-bound probe job next to a long-lived neighbour.
    let probe = job(2, 360, 600.0, 1.0, 0.9);
    let rec = coupled_sched().run(vec![neighbour(5_000.0), probe.clone()]);
    assert!(
        rec[&2].placement.cells_used() > 1,
        "probe not multi-cell: {:?}",
        rec[&2].placement.nodes_per_cell
    );
    let stretched = rec[&2].end_time - rec[&2].start_time;
    assert!(stretched > 600.0 + 1.0, "no stretch: {stretched}");

    // The same probe alone (no neighbour): still multi-cell-coupled to
    // its own spread at most, but without the neighbour's cross load.
    let alone = coupled_sched().run(vec![probe.clone()]);
    let alone_dur = alone[&2].end_time - alone[&2].start_time;
    assert!(
        stretched > alone_dur,
        "neighbour added no stretch: {stretched} vs {alone_dur}"
    );

    // A compute-bound twin in exactly the same spot is untouched.
    let mut compute = probe;
    compute.comm_fraction = 0.0;
    let rec = coupled_sched().run(vec![neighbour(5_000.0), compute]);
    let dur = rec[&2].end_time - rec[&2].start_time;
    assert!((dur - 600.0).abs() < 1e-9, "compute-bound stretched: {dur}");
}

/// (a, dynamic) When the neighbour ends mid-flight, the running job's
/// provisional End is re-timed *earlier* — congestion relief shortens
/// it relative to a neighbour that stays — and Retime events appear on
/// the shared stream for observers.
#[test]
fn neighbour_departure_retimes_end_earlier() {
    let probe = || job(2, 360, 3_000.0, 1.0, 0.9);
    // Neighbour outlives the probe entirely.
    let full = coupled_sched().run(vec![neighbour(10_000.0), probe()]);
    // Neighbour leaves while the probe is still running.
    let mut probe_events = RetimeProbe::default();
    let mid = coupled_sched().run_with(
        vec![neighbour(1_000.0), probe()],
        Vec::new(),
        &mut [&mut probe_events],
    );
    assert_eq!(
        full[&2].start_time, mid[&2].start_time,
        "same placement instant in both scenarios"
    );
    let full_dur = full[&2].end_time - full[&2].start_time;
    let mid_dur = mid[&2].end_time - mid[&2].start_time;
    assert!(
        mid_dur < full_dur - 1e-3,
        "departure did not pull the End earlier: {mid_dur} vs {full_dur}"
    );
    assert!(mid_dur > 3_000.0, "still stretched vs nominal: {mid_dur}");
    assert!(
        probe_events.retimes > 0,
        "no Retime event reached the observers"
    );
}

/// (b) A CapChange mid-job shifts the running job's End (cap coupling);
/// without coupling the End stays frozen at its start-time value.
#[test]
fn cap_change_mid_job_shifts_end() {
    let cap = PowerCap {
        cap_mw: 99.0,
        node_watts: 2238.0,
        idle_watts: 365.0,
    };
    let events = || vec![ScheduledEvent::at(50.0, Event::CapChange { cap_mw: Some(4.0) })];
    let run = |coupling: Coupling| {
        let mut s = Scheduler::with_coupling(&MachineConfig::leonardo(), coupling);
        s.power_cap = Some(cap);
        s.run_with(vec![job(1, 3000, 100.0, 0.0, 0.0)], events(), &mut [])
    };
    let frozen = run(Coupling::default());
    assert_eq!(frozen[&1].end_time, 100.0, "uncoupled End moved");
    let coupled = run(Coupling::full());
    assert!(
        coupled[&1].end_time > 100.0,
        "cap change did not stretch the running job: {}",
        coupled[&1].end_time
    );
    // 50 s at nominal, the rest at the 4 MW DVFS workpoint.
    let draw_mw = (3000.0 * 2238.0 + 456.0 * 365.0) / 1e6;
    let scale = (4.0 / draw_mw).sqrt().clamp(0.5, 1.0);
    let expected = 50.0 + 50.0 * (1.0 / scale);
    assert!(
        (coupled[&1].end_time - expected).abs() < 1e-9,
        "{} vs {expected}",
        coupled[&1].end_time
    );
    // Lifting the cap mid-stretch pulls the End back in.
    let mut s = Scheduler::with_coupling(&MachineConfig::leonardo(), Coupling::full());
    s.power_cap = Some(PowerCap { cap_mw: 4.0, ..cap });
    let relieved = s.run_with(
        vec![job(1, 3000, 100.0, 0.0, 0.0)],
        vec![ScheduledEvent::at(50.0, Event::CapChange { cap_mw: None })],
        &mut [],
    );
    let throttled_end = 100.0 / scale; // fully capped baseline
    assert!(
        relieved[&1].end_time < throttled_end,
        "cap lift did not shorten the job: {} vs {throttled_end}",
        relieved[&1].end_time
    );
    assert!(relieved[&1].end_time > 100.0, "ran faster than nominal");
    // The job finished at nominal clocks, but the throttled interval
    // stays on the books.
    assert_eq!(relieved[&1].dvfs_scale, 1.0, "final workpoint is nominal");
    assert!(
        relieved[&1].min_dvfs_scale < 1.0,
        "capped interval lost from the record"
    );
}

/// A cap move on fully memory-bound work changes *power*, not runtime:
/// the End stays put (time factor is 1 at any scale) but a Retime still
/// reaches observers so the energy books see the capped interval, and
/// the record carries the new workpoint.
#[test]
fn cap_change_on_memory_bound_job_retimes_power_not_end() {
    let mut s = Scheduler::with_coupling(
        &MachineConfig::leonardo(),
        Coupling {
            congestion: false,
            cap: true,
        },
    );
    s.power_cap = Some(PowerCap {
        cap_mw: 99.0,
        node_watts: 2238.0,
        idle_watts: 365.0,
    });
    let mut j = job(1, 3000, 100.0, 0.0, 0.0);
    j.boundness = 0.0;
    let mut probe = RetimeProbe::default();
    let rec = s.run_with(
        vec![j],
        vec![ScheduledEvent::at(50.0, Event::CapChange { cap_mw: Some(4.0) })],
        &mut [&mut probe],
    );
    assert_eq!(rec[&1].end_time, 100.0, "memory-bound runtime unaffected");
    assert!(rec[&1].dvfs_scale < 1.0, "record missing the capped workpoint");
    assert!(probe.retimes > 0, "observers never heard the power change");
}

/// (c) Coupled sweep reports are bit-for-bit identical for 1, 2 and 8
/// worker threads — retiming is deterministic per scenario, and the
/// merge is thread-count independent. The grid carries the policy axis,
/// so the identity covers both placement policies per scenario.
#[test]
fn coupled_sweep_identical_across_thread_counts() {
    let twin = Twin::leonardo();
    let grid = SweepGrid::new(
        vec![1, 2, 3, 4],
        vec![None, Some(7.5), Some(6.0)],
        vec!["day".into(), "ai".into()],
        100,
    )
    .unwrap()
    .with_coupling(Coupling::full())
    .with_policies(vec![PolicyKind::PackFirst, PolicyKind::SpreadLinks]);
    assert_eq!(grid.len(), 48);
    let r1 = run_sweep(&twin, &grid, 1);
    let r2 = run_sweep(&twin, &grid, 2);
    let r8 = run_sweep(&twin, &grid, 8);
    assert_eq!(r1, r2, "coupled 1-thread vs 2-thread reports differ");
    assert_eq!(r1, r8, "coupled 1-thread vs 8-thread reports differ");
    assert_eq!(r1.stats.len(), 48);
    assert_eq!(
        r1.scenario_table().to_markdown(),
        r8.scenario_table().to_markdown()
    );
    assert_eq!(r1.cap_table().to_markdown(), r8.cap_table().to_markdown());
    assert_eq!(
        r1.summary_table().to_markdown(),
        r8.summary_table().to_markdown()
    );
}

/// ISSUE 4 tentpole identity: the incremental cell-indexed retimer is
/// bit-for-bit the retained retime-all oracle
/// ([`Scheduler::retime_all`]) across a coupled HPC day — both routing
/// policies, with and without a mid-day `CapChange` — and, absent
/// injected events, also bit-for-bit the PR 1-cost baseline engine
/// (which always re-times all).
#[test]
fn incremental_retiming_matches_retime_all_oracle() {
    let jobs = TraceGen::booster_hpc_day(500, 7).generate();
    let cap = PowerCap {
        cap_mw: 99.0,
        node_watts: 2238.0,
        idle_watts: 365.0,
    };
    for routing in [Routing::Minimal, Routing::Valiant, Routing::Adaptive] {
        for mid_day_cap in [false, true] {
            let events = || {
                if mid_day_cap {
                    vec![ScheduledEvent::at(20_000.0, Event::CapChange { cap_mw: Some(5.5) })]
                } else {
                    Vec::new()
                }
            };
            let build = |retime_all: bool| {
                let mut s = Scheduler::with_coupling(&MachineConfig::leonardo(), Coupling::full());
                if let Some(net) = s.net.as_mut() {
                    net.routing = routing;
                }
                s.power_cap = Some(cap);
                s.retime_all = retime_all;
                s
            };
            let mut fast_sched = build(false);
            let fast = fast_sched.run_with(jobs.clone(), events(), &mut []);
            let oracle = build(true).run_with(jobs.clone(), events(), &mut []);
            assert_eq!(fast.len(), oracle.len());
            for (id, f) in &fast {
                let o = &oracle[id];
                let ctx = format!("routing {routing:?} cap {mid_day_cap} job {id}");
                assert_eq!(f.start_time, o.start_time, "{ctx}");
                assert_eq!(f.end_time, o.end_time, "{ctx}");
                assert_eq!(f.dvfs_scale, o.dvfs_scale, "{ctx}");
                assert_eq!(f.min_dvfs_scale, o.min_dvfs_scale, "{ctx}");
                assert_eq!(f.placement.nodes_per_cell, o.placement.nodes_per_cell, "{ctx}");
            }
            if !mid_day_cap {
                // The PR 1 baseline engine (always retime-all) agrees too.
                let base = build(false).run_event_baseline(jobs.clone());
                for (id, f) in &fast {
                    let b = &base[id];
                    assert_eq!(f.end_time, b.end_time, "baseline job {id}");
                    assert_eq!(f.start_time, b.start_time, "baseline job {id}");
                }
            }
            // The index must actually elide work on an HPC day, or the
            // whole exercise is a no-op.
            assert!(
                fast_sched.last_run.retimes_elided > 0,
                "incremental engine elided nothing (routing {routing:?})"
            );
        }
    }
}

/// Elision is pure bookkeeping: every report number of a coupled sweep
/// is identical between the incremental engine and the retime-all
/// baseline — `retimes_elided` (and the machinery behind it) never
/// changes anything it reports next to.
#[test]
fn retimes_elided_is_report_neutral() {
    let twin = Twin::leonardo();
    for seed in [1u64, 9] {
        let grid = SweepGrid::new(
            vec![seed, seed + 1],
            vec![None, Some(6.5)],
            vec!["hpc".into()],
            120,
        )
        .unwrap()
        .with_coupling(Coupling::full());
        let fast = run_sweep_streaming(&twin, &grid, 2);
        let oracle = run_sweep_streaming(&twin, &grid.clone().with_retime_all(true), 2);
        assert_eq!(fast.stats.len(), oracle.stats.len());
        for (a, b) in fast.stats.iter().zip(&oracle.stats) {
            let ctx = format!("seed {} cap {:?}", a.seed, a.cap_mw);
            assert_eq!(a.makespan_h, b.makespan_h, "{ctx}");
            assert_eq!(a.mean_wait_min, b.mean_wait_min, "{ctx}");
            assert_eq!(a.p95_wait_min, b.p95_wait_min, "{ctx}");
            assert_eq!(a.max_wait_min, b.max_wait_min, "{ctx}");
            assert_eq!(a.utilization, b.utilization, "{ctx}");
            assert_eq!(a.peak_mw, b.peak_mw, "{ctx}");
            assert_eq!(a.energy_mwh, b.energy_mwh, "{ctx}");
            assert_eq!(a.throttled, b.throttled, "{ctx}");
            assert_eq!(a.peak_congestion, b.peak_congestion, "{ctx}");
            assert_eq!(a.mean_stretch, b.mean_stretch, "{ctx}");
            assert_eq!(a.p95_stretch, b.p95_stretch, "{ctx}");
            assert_eq!(a.events_skipped, b.events_skipped, "{ctx}");
        }
    }
}

/// ISSUE 5: an explicitly installed PackFirst policy is bit-for-bit
/// the pre-policy scheduler with coupling on — placement pluggability
/// cannot move a single coupled number.
#[test]
fn pack_first_policy_is_identity_under_coupling() {
    let jobs = TraceGen::booster_hpc_day(400, 5).generate();
    let plain = coupled_sched().run(jobs.clone());
    let mut s = coupled_sched();
    s.set_policy(PolicyKind::PackFirst);
    let explicit = s.run(jobs);
    assert_eq!(plain.len(), explicit.len());
    for (id, r) in &explicit {
        let p = &plain[id];
        assert_eq!(r.start_time, p.start_time, "job {id}");
        assert_eq!(r.end_time, p.end_time, "job {id}");
        assert_eq!(r.placement.nodes_per_cell, p.placement.nodes_per_cell, "job {id}");
    }
}

/// A 4-cell Booster-only machine — small enough that the contended
/// two-cell trace below is fully hand-analyzable.
fn mini_booster() -> MachineConfig {
    let mut cfg = MachineConfig::leonardo();
    cfg.name = "MiniBooster".into();
    cfg.cells = (0..4)
        .map(|_| CellConfig {
            kind: CellKind::Booster,
            groups: vec![RackGroup {
                racks: 6,
                blades_per_rack: 30,
                nodes_per_blade: 1,
                node: NodeSpec::davinci(),
            }],
        })
        .collect();
    cfg
}

/// ISSUE 5 acceptance: on a contended two-cell trace the neighbour's
/// stretch is strictly lower under SpreadLinks than under PackFirst.
/// The layout is fully determined: X (240 nodes, comm-bound) spans
/// cells {0, 1}; a 120-node single-cell filler arrives, then a
/// comm-bound 240-node probe P. PackFirst packs the filler into clean
/// cell 2 and P into {3, 1} — overlapping X on cell 1, so X and P
/// stretch each other. SpreadLinks parks the (link-immune) filler on
/// X's cell 1 and routes P through clean {2, 3} — X never sees P's
/// traffic.
#[test]
fn spread_links_lowers_neighbour_stretch_on_contended_two_cell_trace() {
    let cfg = mini_booster();
    let jobs = || {
        vec![
            job(1, 240, 4_000.0, 0.0, 0.9),  // X: the two-cell neighbour
            job(2, 120, 10_000.0, 1.0, 0.0), // filler: single-cell, immune
            job(3, 240, 600.0, 2.0, 0.9),    // P: the contending probe
        ]
    };
    let run = |policy: PolicyKind| {
        let mut s = Scheduler::with_coupling(&cfg, Coupling::full());
        s.set_policy(policy);
        s.run(jobs())
    };
    let pack = run(PolicyKind::PackFirst);
    let spread = run(PolicyKind::SpreadLinks);
    // Exact placements: the probe overlaps X under PackFirst and avoids
    // it (via the parked filler) under SpreadLinks.
    assert_eq!(pack[&1].placement.nodes_per_cell, vec![(0, 180), (1, 60)]);
    assert_eq!(pack[&2].placement.nodes_per_cell, vec![(2, 120)]);
    assert_eq!(pack[&3].placement.nodes_per_cell, vec![(3, 180), (1, 60)]);
    assert_eq!(spread[&1].placement.nodes_per_cell, vec![(0, 180), (1, 60)]);
    assert_eq!(spread[&2].placement.nodes_per_cell, vec![(1, 120)]);
    assert_eq!(spread[&3].placement.nodes_per_cell, vec![(2, 180), (3, 60)]);
    // Same start instants, strictly less neighbour (and probe) stretch.
    assert_eq!(pack[&1].start_time, spread[&1].start_time);
    assert_eq!(pack[&3].start_time, spread[&3].start_time);
    let pack_x = pack[&1].end_time - pack[&1].start_time;
    let spread_x = spread[&1].end_time - spread[&1].start_time;
    assert!(spread_x < pack_x - 1.0, "neighbour stretch not reduced: {spread_x} vs {pack_x}");
    let pack_p = pack[&3].end_time - pack[&3].start_time;
    let spread_p = spread[&3].end_time - spread[&3].start_time;
    assert!(spread_p < pack_p - 1.0, "probe stretch not reduced: {spread_p} vs {pack_p}");
    // Both worlds still stretch X beyond nominal: its own two-cell
    // spread is the first congestion source.
    assert!(spread_x > 4_000.0, "{spread_x}");
}

/// ISSUE 5 acceptance: on a contended `day` mix, a coupled policy-axis
/// sweep scores SpreadLinks strictly better than PackFirst on mean p95
/// runtime stretch, and the report's policy table surfaces the
/// comparison.
#[test]
fn spread_links_reduces_p95_stretch_on_contended_day_sweep() {
    let twin = Twin::leonardo();
    // 8000 jobs saturate the Booster day: queues form, free space
    // fragments, and comm-bound jobs land in multi-cell placements
    // next to each other — the regime placement policy exists for.
    // One seed keeps the (debug-profile) suite affordable; the same
    // claim is gated at bench scale in campaign_throughput.
    let grid = SweepGrid::new(vec![1], vec![None], vec!["day".into()], 8_000)
        .unwrap()
        .with_coupling(Coupling::full())
        .with_policies(vec![PolicyKind::PackFirst, PolicyKind::SpreadLinks]);
    assert_eq!(grid.len(), 2);
    let report = run_sweep_streaming(&twin, &grid, 2);
    let mean_p95 = |policy: PolicyKind| {
        let vals: Vec<f64> = report
            .stats
            .iter()
            .filter(|s| s.policy == policy)
            .map(|s| s.p95_stretch)
            .collect();
        assert_eq!(vals.len(), 1);
        vals.iter().sum::<f64>() / vals.len() as f64
    };
    let pack = mean_p95(PolicyKind::PackFirst);
    let spread = mean_p95(PolicyKind::SpreadLinks);
    assert!(pack > 1.0, "day mix not contended: pack p95 stretch {pack}");
    assert!(spread < pack, "SpreadLinks did not reduce mean p95 stretch: {spread} vs {pack}");
    let pt = report.policy_table();
    assert_eq!(pt.rows.len(), 2, "policy comparison rows missing");
    assert_eq!(pt.rows[0][0], "pack");
    assert_eq!(pt.rows[1][0], "spread");
}

/// Coupled accounting stays safe: all jobs complete, the machine drains
/// back to fully free, and no instant oversubscribes the partition even
/// though End times move around.
#[test]
fn coupled_replay_keeps_accounting_invariants() {
    let jobs = TraceGen::booster_hpc_day(800, 23).generate();
    let mut s = coupled_sched();
    s.power_cap = Some(PowerCap {
        cap_mw: 6.5,
        node_watts: 2238.0,
        idle_watts: 365.0,
    });
    let recs = s.run(jobs.clone());
    assert_eq!(recs.len(), jobs.len());
    assert_eq!(s.free_nodes(Partition::Booster), 3456);
    let mut events: Vec<(f64, i64)> = Vec::new();
    for j in &jobs {
        let r = &recs[&j.id];
        assert!(r.end_time > r.start_time, "job {} ran backwards", j.id);
        events.push((r.start_time, j.nodes as i64));
        events.push((r.end_time, -(j.nodes as i64)));
    }
    events.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    let mut load = 0i64;
    for (_, delta) in events {
        load += delta;
        assert!(load <= 3456, "booster oversubscribed: {load}");
    }
}
