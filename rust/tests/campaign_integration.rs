//! Cross-module integration: the full campaign layer (config + topology +
//! network + storage + scheduler + power + perfmodel + lbm) reproduces
//! every table of the paper within tolerance, end to end, with no PJRT
//! dependency (pure simulation path).

use leonardo_twin::coordinator::Twin;
use leonardo_twin::power::Utilization;
use leonardo_twin::scheduler::{CheckpointPolicy, Job, Partition, PowerCap, Scheduler};
use leonardo_twin::workloads::AppBenchmark;

fn cell(t: &leonardo_twin::metrics::Table, row: usize, col: usize) -> f64 {
    t.rows[row][col].parse().unwrap()
}

#[test]
fn every_paper_table_regenerates() {
    let twin = Twin::leonardo();
    // Table 1 totals.
    let t1 = twin.table1();
    assert_eq!(t1.rows.last().unwrap()[4], "3456");
    // Table 2 has 16 metric rows x 3 GPUs.
    let t2 = twin.table2();
    assert_eq!(t2.rows.len(), 16);
    // Table 3 rows: /home /archive /scratch.
    let t3 = twin.table3();
    assert_eq!(t3.rows.len(), 3);
    // Table 4 headline numbers.
    let t4 = twin.table4(None);
    assert!((cell(&t4, 0, 1) - 238.7).abs() / 238.7 < 0.03); // Rmax
    assert!((cell(&t4, 3, 1) - 3.11).abs() / 3.11 < 0.03); // HPCG
    assert!((cell(&t4, 4, 1) - 7.4).abs() / 7.4 < 0.03); // MW
    assert!((cell(&t4, 5, 1) - 32.2).abs() / 32.2 < 0.05); // Green500
    // Table 5 score.
    let t5 = twin.table5();
    let score = t5.rows.last().unwrap()[1].parse::<f64>().unwrap();
    assert!((score - 649.0).abs() / 649.0 < 0.05, "{score}");
    // Table 6: TTS within 1%, ETS within 5% per app.
    let t6 = twin.table6().unwrap();
    for row in &t6.rows {
        let tts: f64 = row[3].parse().unwrap();
        let tts_paper: f64 = row[4].parse().unwrap();
        assert!((tts - tts_paper).abs() / tts_paper < 0.02, "{row:?}");
        let ets: f64 = row[5].parse().unwrap();
        let ets_paper: f64 = row[6].parse().unwrap();
        assert!((ets - ets_paper).abs() / ets_paper < 0.06, "{row:?}");
    }
    // Table 7: shape within banded tolerance; headline LUPS within 10%.
    let t7 = twin.table7(None).unwrap();
    let last = t7.rows.last().unwrap();
    let tlups: f64 = last[2].parse().unwrap();
    assert!((tlups - 51.2).abs() / 51.2 < 0.10, "{tlups}");
}

#[test]
fn fig5_leonardo_scales_at_least_as_well_as_marconi() {
    let t = Twin::leonardo().fig5().unwrap();
    for row in t.rows.iter().skip(1) {
        if row[2] == "-" {
            continue;
        }
        let leo: f64 = row[1].parse().unwrap();
        let mar: f64 = row[2].parse().unwrap();
        assert!(leo >= mar - 0.01, "GPUs={} leo={leo} mar={mar}", row[0]);
    }
}

#[test]
fn scheduler_campaign_under_power_cap_completes_and_throttles() {
    let twin = Twin::leonardo();
    let mut sched = Scheduler::new(&twin.cfg);
    sched.power_cap = Some(PowerCap {
        cap_mw: 5.0,
        node_watts: twin.power.node_power_w(Utilization::hpl()),
        idle_watts: twin.power.node_power_w(Utilization::idle()),
    });
    let jobs: Vec<Job> = (0..20)
        .map(|i| Job {
            id: i,
            partition: Partition::Booster,
            nodes: 400 + (i as u32 % 5) * 300,
            est_seconds: 100.0,
            run_seconds: 90.0,
            submit_time: (i as f64) * 5.0,
            boundness: 0.7,
            comm_fraction: 0.2,
            checkpoint: CheckpointPolicy::None,
        })
        .collect();
    let recs = sched.run(jobs.clone());
    assert_eq!(recs.len(), 20);
    // Under a 5 MW cap with 2.2 kW nodes, concurrent load must throttle.
    let throttled = recs.values().filter(|r| r.dvfs_scale < 1.0).count();
    assert!(throttled > 0, "no job was throttled under the cap");
    for j in &jobs {
        let r = &recs[&j.id];
        assert!(r.end_time - r.start_time >= j.run_seconds - 1e-6);
    }
}

#[test]
fn app_sweeps_compose_with_scheduler_placements() {
    let twin = Twin::leonardo();
    for app in AppBenchmark::table6() {
        let mut last_tts = f64::INFINITY;
        for factor in [1u32, 2, 4] {
            let nodes = app.ref_nodes * factor;
            let placement = twin.place(nodes).unwrap();
            let tts = app.tts(nodes, &twin.net, &placement);
            assert!(tts < last_tts, "{}: no speedup at {nodes}", app.name);
            assert!(tts > 0.0);
            last_tts = tts;
        }
    }
}

#[test]
fn marconi_twin_is_self_consistent() {
    let m = Twin::marconi100();
    assert_eq!(m.cfg.gpu_nodes(), 980);
    assert!(m.net.oversubscription > 1.0);
    // Its largest possible job still places.
    let p = m.place(980).unwrap();
    assert_eq!(p.total_nodes(), 980);
    // Per-GPU LBM rate ~ 2.5x slower than LEONARDO's (Appendix A.3).
    let leo = Twin::leonardo();
    let leo_node = leo.cfg.gpu_node_spec().unwrap();
    let m_node = m.cfg.gpu_node_spec().unwrap();
    use leonardo_twin::lbm::{LbmConfig, LbmDriver};
    let rl = LbmDriver::new(leo_node, &leo.net, LbmConfig::default()).per_gpu_lups();
    let rm = LbmDriver::new(m_node, &m.net, LbmConfig::default()).per_gpu_lups();
    assert!((rl / rm - 2.5).abs() < 0.2, "{}", rl / rm);
}

#[test]
fn latency_budget_matches_paper_bounds() {
    let twin = Twin::leonardo();
    let t = twin.latency_table();
    // All paths between 1 and 3 us; NIC floor 1.2 us everywhere.
    for row in &t.rows {
        let us: f64 = row[2].parse().unwrap();
        assert!(us >= 1.2 && us <= 3.0, "{row:?}");
    }
}
