//! Scheduler invariants on the event-driven engine: equivalence with the
//! legacy rescan loop, EASY backfill head protection, node-accounting
//! safety (no double release, no oversubscription) and determinism of
//! the 10k-job mixed HPC+AI day trace.

use leonardo_twin::config::MachineConfig;
use leonardo_twin::network::CongestionTracker;
use leonardo_twin::power::{PowerModel, PowerMonitor, Utilization};
use leonardo_twin::scheduler::{CheckpointPolicy, Job, JobRecord, Partition, Scheduler};
use leonardo_twin::sim::Component;
use leonardo_twin::telemetry::EventCounter;
use leonardo_twin::util::rng::Rng;
use leonardo_twin::workloads::TraceGen;

use std::collections::BTreeMap;

fn sched() -> Scheduler {
    Scheduler::new(&MachineConfig::leonardo())
}

fn job(id: u64, nodes: u32, secs: f64, submit: f64) -> Job {
    Job {
        id,
        partition: Partition::Booster,
        nodes,
        est_seconds: secs,
        run_seconds: secs,
        submit_time: submit,
        boundness: 1.0,
        comm_fraction: 0.0,
        checkpoint: CheckpointPolicy::None,
    }
}

fn assert_identical(a: &BTreeMap<u64, JobRecord>, b: &BTreeMap<u64, JobRecord>, tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: record counts differ");
    for (id, ra) in a {
        let rb = &b[id];
        assert_eq!(ra.start_time, rb.start_time, "{tag}: job {id} start");
        assert_eq!(ra.end_time, rb.end_time, "{tag}: job {id} end");
        assert_eq!(ra.dvfs_scale, rb.dvfs_scale, "{tag}: job {id} scale");
        assert_eq!(
            ra.placement.nodes_per_cell, rb.placement.nodes_per_cell,
            "{tag}: job {id} placement"
        );
    }
}

/// The event engine reproduces the legacy loop bit-for-bit on random
/// dual-partition streams.
#[test]
fn event_engine_equals_rescan_on_random_streams() {
    for seed in 0..8u64 {
        let mut rng = Rng::new(seed);
        let n_jobs = rng.range_u32(20, 120);
        let jobs: Vec<Job> = (0..n_jobs)
            .map(|i| {
                let booster = rng.f64() < 0.7;
                Job {
                    id: i as u64,
                    partition: if booster {
                        Partition::Booster
                    } else {
                        Partition::DataCentric
                    },
                    nodes: rng.range_u32(1, if booster { 3456 } else { 1536 }),
                    est_seconds: rng.range_f64(1.0, 500.0),
                    run_seconds: rng.range_f64(1.0, 500.0),
                    submit_time: rng.range_f64(0.0, 100.0),
                    boundness: rng.f64(),
                    comm_fraction: rng.f64() * 0.5,
                    checkpoint: CheckpointPolicy::None,
                }
            })
            .collect();
        let ev = sched().run(jobs.clone());
        let baseline = sched().run_event_baseline(jobs.clone());
        let legacy = sched().run_rescan(jobs);
        assert_identical(&ev, &legacy, &format!("seed {seed}"));
        assert_identical(&ev, &baseline, &format!("seed {seed} (event baseline)"));
    }
}

/// Same equivalence on a realistic 1k-job mixed HPC+AI trace — the
/// optimized hot path (cached placement order, settled-prefix scans,
/// min-queued pruning) against both the PR 1 event engine and the seed
/// loop.
#[test]
fn event_engine_equals_rescan_on_mixed_trace() {
    let jobs = TraceGen::booster_day(1000, 17).generate();
    let ev = sched().run(jobs.clone());
    let baseline = sched().run_event_baseline(jobs.clone());
    let legacy = sched().run_rescan(jobs);
    assert_identical(&ev, &legacy, "mixed trace");
    assert_identical(&ev, &baseline, "mixed trace (event baseline)");
}

/// The optimized placement path under a facility power cap stays
/// bit-for-bit on the DVFS decisions too (the cap couples every start
/// to the global busy-node count, so any skipped-or-reordered pass
/// would show up here).
#[test]
fn optimized_path_equals_baseline_under_cap_on_mixed_trace() {
    use leonardo_twin::scheduler::PowerCap;
    let jobs = TraceGen::booster_day(800, 29).generate();
    let cap = PowerCap {
        cap_mw: 5.0,
        node_watts: 2238.0,
        idle_watts: 365.0,
    };
    let mut a = sched();
    a.power_cap = Some(cap);
    let ev = a.run(jobs.clone());
    let mut b = sched();
    b.power_cap = Some(cap);
    let baseline = b.run_event_baseline(jobs.clone());
    let mut c = sched();
    c.power_cap = Some(cap);
    let legacy = c.run_rescan(jobs);
    for (id, r) in &ev {
        assert_eq!(r.dvfs_scale, baseline[id].dvfs_scale, "job {id} scale (base)");
        assert_eq!(r.dvfs_scale, legacy[id].dvfs_scale, "job {id} scale (legacy)");
    }
    assert_identical(&ev, &baseline, "capped trace (event baseline)");
    assert_identical(&ev, &legacy, "capped trace (legacy)");
}

/// The three engines stay bit-for-bit identical under the SpreadLinks
/// policy too: `place` and `place_scan` route through the same policy
/// object, so the oracle suites cover both engines per policy (no
/// silent divergence between optimized and baseline paths).
#[test]
fn engines_agree_on_mixed_trace_under_spread_links() {
    use leonardo_twin::scheduler::PolicyKind;
    let cfg = MachineConfig::leonardo();
    let jobs = TraceGen::booster_day(800, 13).generate();
    let spread = || Scheduler::with_policy(&cfg, PolicyKind::SpreadLinks);
    let ev = spread().run(jobs.clone());
    let baseline = spread().run_event_baseline(jobs.clone());
    let legacy = spread().run_rescan(jobs);
    assert_identical(&ev, &legacy, "spread mixed trace");
    assert_identical(&ev, &baseline, "spread mixed trace (event baseline)");
}

/// EASY backfill must never delay the queue head: injecting a stream of
/// backfill candidates leaves the head's start time exactly where it was
/// without them.
#[test]
fn easy_backfill_never_delays_queue_head() {
    // Job 1 occupies most of the machine until t=100; the head (job 2)
    // needs the whole machine. Short narrow jobs may run in the hole.
    let blocker = job(1, 3000, 100.0, 0.0);
    let head = job(2, 3456, 50.0, 1.0);

    let baseline = sched().run(vec![blocker.clone(), head.clone()]);
    let head_start = baseline[&2].start_time;
    assert!((head_start - 100.0).abs() < 1e-9);

    let mut with_backfill = vec![blocker, head];
    // 30 backfill candidates that fit in the 456-node hole and finish
    // before t=100.
    for i in 0..30u64 {
        with_backfill.push(job(10 + i, 10, 40.0, 2.0 + i as f64 * 0.1));
    }
    let recs = sched().run(with_backfill);
    assert_eq!(
        recs[&2].start_time, head_start,
        "backfill delayed the queue head"
    );
    // And the candidates did actually backfill ahead of the head.
    let backfilled = (10..40u64)
        .filter(|id| recs[id].start_time < head_start)
        .count();
    assert!(backfilled > 0, "no job backfilled into the hole");
}

/// Node accounting: every release returns exactly the placed nodes (the
/// scheduler asserts on double release internally), the machine drains
/// back to fully free, and no instant oversubscribes either partition.
#[test]
fn no_double_release_and_no_oversubscription() {
    let jobs = TraceGen::booster_day(2000, 23).generate();
    let mut s = sched();
    let recs = s.run(jobs.clone());
    assert_eq!(recs.len(), jobs.len());
    assert_eq!(s.free_nodes(Partition::Booster), 3456);
    assert_eq!(s.free_nodes(Partition::DataCentric), 1536);

    // Sweep start/end events: booster load must never exceed capacity.
    let mut events: Vec<(f64, i64)> = Vec::new();
    for j in &jobs {
        let r = &recs[&j.id];
        events.push((r.start_time, j.nodes as i64));
        events.push((r.end_time, -(j.nodes as i64)));
    }
    events.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    let mut load = 0i64;
    for (_, delta) in events {
        load += delta;
        assert!(load <= 3456, "booster oversubscribed: {load}");
    }
}

/// The flagship scenario: a 10k-job mixed day replays identically across
/// two full runs (generator and engine are both deterministic).
#[test]
fn trace_10k_deterministic_across_runs() {
    let trace = TraceGen::booster_day(10_000, 2023);
    let jobs_a = trace.generate();
    let jobs_b = trace.generate();
    let rec_a = sched().run(jobs_a);
    let rec_b = sched().run(jobs_b);
    assert_identical(&rec_a, &rec_b, "10k trace");
    assert_eq!(rec_a.len(), 10_000);
}

/// Conservation under faults: stepping a faulted session in fixed
/// increments keeps `free + down + running == total` at every pause
/// point, checkpoint-requeued jobs all complete, and after the last
/// repair the machine drains back to fully free.
#[test]
fn faulted_session_conserves_nodes_at_every_step() {
    use leonardo_twin::scheduler::ReplaySession;
    use leonardo_twin::sim::Simulation;
    use leonardo_twin::workloads::FaultTrace;

    let cfg = MachineConfig::leonardo();
    let mut trace = TraceGen::booster_day(800, 7);
    trace.checkpoint = Some(CheckpointPolicy::Periodic(1800.0));
    let jobs = trace.generate();
    let faults = FaultTrace {
        seed: 11,
        duration_s: 86_400.0,
        node_mtbf_s: 5.0e5,
        repair_mean_s: 7_200.0,
        group: 32,
        ..FaultTrace::none()
    };
    let extra = faults.events(&cfg);
    assert!(!extra.is_empty(), "fault trace armed no failure process");

    let mut s = sched();
    let mut sim = Simulation::new();
    let mut session = ReplaySession::new(&mut sim, &mut s, jobs.clone(), extra);
    let mut obs: [&mut dyn Component; 0] = [];
    let mut t = 0.0;
    while t < 2.0 * 86_400.0 {
        t += 900.0;
        session.run_until(t, &mut obs);
        session.assert_conserved();
    }
    session.run_to_end(&mut obs);
    session.assert_conserved();
    session.assert_complete();

    let counters = session.counters();
    assert!(counters.killed > 0, "no job overlapped a node failure");
    assert_eq!(
        counters.requeued, counters.killed,
        "every job carries a periodic checkpoint, so every kill requeues"
    );
    assert!(counters.wasted_node_seconds > 0.0, "kills wasted no work");
    assert!(counters.recovery_p95 >= 1.0, "recovery cannot beat nominal");

    let recs = session.finish();
    assert_eq!(recs.len(), jobs.len(), "a killed job never completed");
    // Every NodeUp is paired with its NodeDown inside the trace, so
    // once the queue drains the machine is whole again.
    assert_eq!(s.free_nodes(Partition::Booster), 3456);
    assert_eq!(s.free_nodes(Partition::DataCentric), 1536);
}

/// Observers on the shared event stream stay consistent with the job
/// records: lifecycle counts match, busy nodes drain to zero, power
/// series integrate to positive energy and congestion returns to idle.
#[test]
fn observers_agree_with_records() {
    let cfg = MachineConfig::leonardo();
    let jobs = TraceGen::booster_day(500, 5).generate();
    let mut s = Scheduler::new(&cfg);
    let model = PowerModel::new(leonardo_twin::hardware::NodeSpec::davinci(), 1.1);
    let mut monitor = PowerMonitor::new(
        model,
        Utilization {
            cpu: 0.4,
            gpu: Some(0.8),
        },
        3456,
    );
    let mut congestion = CongestionTracker::for_booster(&cfg);
    let mut counter = EventCounter::default();
    let recs = {
        let mut obs: [&mut dyn Component; 3] = [&mut monitor, &mut congestion, &mut counter];
        s.run_with(jobs.clone(), Vec::new(), &mut obs)
    };
    assert_eq!(recs.len(), 500);
    assert_eq!(counter.totals(), (500, 500, 500));
    assert_eq!(monitor.busy_nodes(), 0, "all started nodes released");
    assert!(monitor.energy_kwh() > 0.0);
    assert_eq!(congestion.mean_load(), 0.0, "fabric idle after the day");
    // The store has one utilization sample per start and per end.
    let util = monitor.store.get("utilization").unwrap();
    assert_eq!(util.len(), 1000);
    assert!(util.max() <= 1.0 + 1e-9);
}
