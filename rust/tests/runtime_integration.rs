//! Integration tests across the PJRT runtime boundary: load every AOT
//! artifact, execute it from Rust, and verify *numerics* against
//! physics/algebra invariants computed on the Rust side.
//!
//! Requires `make artifacts` (the Makefile test target guarantees it);
//! each test skips with a notice when artifacts are absent so plain
//! `cargo test` stays green on a fresh checkout.

use leonardo_twin::coordinator::equilibrium_f32;
use leonardo_twin::runtime::{literal_f32, scalar_f32, Engine};

fn engine() -> Option<Engine> {
    match Engine::load(Engine::default_dir()) {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("SKIP (run `make artifacts`): {err:#}");
            None
        }
    }
}

#[test]
fn manifest_covers_expected_modules() {
    let Some(engine) = engine() else { return };
    for name in [
        "lbm_step_32",
        "lbm_steps8_32",
        "dgemm_256",
        "dgemm_512",
        "hpl_update_256",
        "spmv_64",
        "cg_iter_64",
        "cg_iters8_64",
    ] {
        assert!(
            engine.spec(name).is_some(),
            "artifact '{name}' missing from manifest"
        );
    }
}

#[test]
fn lbm_step_conserves_mass_and_is_equilibrium_fixed_point() {
    let Some(engine) = engine() else { return };
    let n = 32usize;
    let sites = n * n * n;
    let f0 = equilibrium_f32(n);
    let f = literal_f32(&f0, &[19, n, n, n]).unwrap();
    let omega = literal_f32(&[1.7f32], &[1]).unwrap();
    let out = engine.execute("lbm_step_32", &[f, omega]).unwrap();
    let result: Vec<f32> = out[0].to_vec().unwrap();
    assert_eq!(result.len(), 19 * sites);
    // Quiescent equilibrium is a fixed point of collide+stream.
    let mut max_dev = 0f32;
    for (a, b) in result.iter().zip(&f0) {
        max_dev = max_dev.max((a - b).abs());
    }
    assert!(max_dev < 1e-5, "equilibrium drifted by {max_dev}");
}

#[test]
fn lbm_step_preserves_perturbed_mass() {
    let Some(engine) = engine() else { return };
    let n = 32usize;
    let _sites = n * n * n;
    let mut f0 = equilibrium_f32(n);
    // Deterministic perturbation.
    let mut rng = leonardo_twin::util::rng::Rng::new(7);
    for v in f0.iter_mut() {
        *v *= 1.0 + 0.05 * (rng.f64() as f32 - 0.5);
    }
    let total0: f64 = f0.iter().map(|&v| v as f64).sum();
    let f = literal_f32(&f0, &[19, n, n, n]).unwrap();
    let omega = literal_f32(&[1.2f32], &[1]).unwrap();
    let out = engine.execute("lbm_steps8_32", &[f, omega]).unwrap();
    let result: Vec<f32> = out[0].to_vec().unwrap();
    let total1: f64 = result.iter().map(|&v| v as f64).sum();
    assert!(
        ((total1 - total0) / total0).abs() < 1e-5,
        "mass drift over 8 steps: {total0} -> {total1}"
    );
    assert!(result.iter().all(|v| v.is_finite()));
}

#[test]
fn dgemm_matches_rust_reference() {
    let Some(engine) = engine() else { return };
    let n = 256usize;
    let mut rng = leonardo_twin::util::rng::Rng::new(42);
    let a: Vec<f32> = (0..n * n).map(|_| rng.f64() as f32 - 0.5).collect();
    let b: Vec<f32> = (0..n * n).map(|_| rng.f64() as f32 - 0.5).collect();
    let out = engine
        .execute(
            "dgemm_256",
            &[
                literal_f32(&a, &[n, n]).unwrap(),
                literal_f32(&b, &[n, n]).unwrap(),
            ],
        )
        .unwrap();
    let c: Vec<f32> = out[0].to_vec().unwrap();
    // Spot-check 64 entries against a straightforward dot product.
    let mut rng = leonardo_twin::util::rng::Rng::new(1);
    for _ in 0..64 {
        let i = (rng.next_u64() % n as u64) as usize;
        let j = (rng.next_u64() % n as u64) as usize;
        let want: f32 = (0..n).map(|k| a[i * n + k] * b[k * n + j]).sum();
        let got = c[i * n + j];
        assert!(
            (got - want).abs() < 1e-2 + want.abs() * 1e-3,
            "c[{i}][{j}] = {got}, want {want}"
        );
    }
}

#[test]
fn hpl_update_is_c_minus_ab() {
    let Some(engine) = engine() else { return };
    let n = 256usize;
    let c0 = vec![1.0f32; n * n];
    let a = vec![0.5f32; n * n];
    let b = vec![0.25f32; n * n];
    let out = engine
        .execute(
            "hpl_update_256",
            &[
                literal_f32(&c0, &[n, n]).unwrap(),
                literal_f32(&a, &[n, n]).unwrap(),
                literal_f32(&b, &[n, n]).unwrap(),
            ],
        )
        .unwrap();
    let c: Vec<f32> = out[0].to_vec().unwrap();
    // C - A@B = 1 - 256 * 0.5 * 0.25 = 1 - 32 = -31 everywhere.
    for (idx, v) in c.iter().enumerate() {
        assert!((v + 31.0).abs() < 1e-2, "c[{idx}] = {v}");
    }
}

#[test]
fn spmv_constant_field_vanishes_in_interior() {
    let Some(engine) = engine() else { return };
    let g = 64usize;
    let x = vec![1.0f32; g * g * g];
    let out = engine
        .execute("spmv_64", &[literal_f32(&x, &[g, g, g]).unwrap()])
        .unwrap();
    let y: Vec<f32> = out[0].to_vec().unwrap();
    // Interior rows of the 27-point operator sum to zero on constants;
    // boundary rows are positive (lost neighbours).
    let idx = |i: usize, j: usize, k: usize| (i * g + j) * g + k;
    assert!(y[idx(32, 32, 32)].abs() < 1e-4);
    assert!(y[idx(0, 0, 0)] > 1.0);
}

#[test]
fn cg_iterations_reduce_residual_norm() {
    let Some(engine) = engine() else { return };
    let g = 64usize;
    let size = g * g * g;
    let mut rng = leonardo_twin::util::rng::Rng::new(3);
    let b: Vec<f32> = (0..size).map(|_| rng.f64() as f32 - 0.5).collect();
    let rz0: f32 = b.iter().map(|v| v * v).sum();

    let x = vec![0.0f32; size];
    let out = engine
        .execute(
            "cg_iters8_64",
            &[
                literal_f32(&x, &[g, g, g]).unwrap(),
                literal_f32(&b, &[g, g, g]).unwrap(),
                literal_f32(&b, &[g, g, g]).unwrap(),
                scalar_f32(rz0).unwrap(),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 4);
    let rz8: f32 = out[3].to_vec::<f32>().unwrap()[0];
    assert!(
        rz8 < rz0 * 1e-2,
        "8 CG iterations reduced rz only {rz0} -> {rz8}"
    );
    assert!(rz8.is_finite() && rz8 >= 0.0);
}

#[test]
fn timing_helper_returns_positive_rates() {
    let Some(engine) = engine() else { return };
    let n = 256usize;
    let a = literal_f32(&vec![1.0f32; n * n], &[n, n]).unwrap();
    let b = literal_f32(&vec![0.5f32; n * n], &[n, n]).unwrap();
    let secs = engine.time_execute("dgemm_256", &[a, b], 2).unwrap();
    assert!(secs > 0.0 && secs < 30.0, "{secs}");
    let gflops = 2.0 * (n as f64).powi(3) / secs / 1e9;
    assert!(gflops > 0.01, "{gflops}");
}

#[test]
fn blocked_lu_with_pjrt_offload_is_correct() {
    let Some(engine) = engine() else { return };
    use leonardo_twin::hpl;
    let n = 512;
    let a0 = hpl::random_matrix(n, 21);
    let mut lu = a0.clone();
    let res = hpl::lu_factor(&mut lu, n, Some(&engine)).unwrap();
    assert!(res.offload_fraction > 0.3, "{}", res.offload_fraction);
    // Solve and check the HPL residual criterion (r < 16 passes).
    let x_true: Vec<f32> = (0..n).map(|i| ((i % 5) as f32) - 2.0).collect();
    let mut b = vec![0f32; n];
    for i in 0..n {
        b[i] = (0..n).map(|j| a0[i * n + j] * x_true[j]).sum();
    }
    let x = hpl::lu_solve(&lu, n, &res.perm, &b);
    let r = hpl::hpl_residual(&a0, n, &x, &b);
    assert!(r < 16.0, "HPL residual {r}");
}

#[test]
fn hpcg_solver_via_pjrt_converges() {
    let Some(engine) = engine() else { return };
    use leonardo_twin::hpcg;
    let points = hpcg::GRID * hpcg::GRID * hpcg::GRID;
    let mut rng = leonardo_twin::util::rng::Rng::new(33);
    let b: Vec<f32> = (0..points).map(|_| rng.f64() as f32 - 0.5).collect();
    let res = hpcg::solve(&engine, &b, 1e-4, 200).unwrap();
    assert!(res.rel_residual < 1e-4, "{}", res.rel_residual);
    assert!(res.iterations >= 8 && res.iterations <= 200);
    assert!(res.gflops > 0.0);
}

#[test]
fn sparse_matmul_artifact_prunes_2_of_4() {
    let Some(engine) = engine() else { return };
    let n = 256usize;
    // x = identity -> output IS the pruned weight matrix.
    let mut x = vec![0f32; n * n];
    for i in 0..n {
        x[i * n + i] = 1.0;
    }
    let mut rng = leonardo_twin::util::rng::Rng::new(55);
    let w: Vec<f32> = (0..n * n).map(|_| rng.f64() as f32 - 0.5).collect();
    let out = engine
        .execute(
            "sparse_matmul_256",
            &[
                literal_f32(&x, &[n, n]).unwrap(),
                literal_f32(&w, &[n, n]).unwrap(),
            ],
        )
        .unwrap();
    let wp: Vec<f32> = out[0].to_vec().unwrap();
    // Every K-group of 4 keeps exactly 2 non-zeros (§2.1.1 sparsity).
    let mut zeros = 0usize;
    for j in 0..n {
        for g in 0..(n / 4) {
            let nz = (0..4)
                .filter(|&q| wp[(4 * g + q) * n + j].abs() > 0.0)
                .count();
            assert!(nz <= 2, "group {g} col {j}: {nz} nonzeros");
            zeros += 4 - nz;
        }
    }
    assert!((zeros as f64 / (n * n) as f64 - 0.5).abs() < 0.01);
}
