//! Chaos harness for the distributed sweep service: seeded wire faults
//! injected under otherwise honest workers prove that a misbehaving
//! link costs the fleet one member — never the report, never the
//! service.
//!
//! Two modes. The *pinned* tests place one [`WireFault`] at an exact
//! protocol position (operation 4 — the length prefix of the worker's
//! first `RowBatch` frame, past `Hello` at ops 0–1 and the first
//! `Next` credit request at ops 2–3) on one half of one worker's
//! connection, and assert the precise failure accounting for every
//! fault kind. The *seeded* tests run the production probe path
//! ([`WorkerOptions::chaos`], the CLI's `work --chaos SEED`) whose
//! schedule is derived from the seed — the same probe the CI chaos
//! step points at a live coordinator.
//!
//! Invariants under every fault, in every test: the coordinator never
//! errors and never hangs, at most the faulted worker is lost, and
//! every report is byte-identical to the single-process oracle.

use std::net::{TcpListener, TcpStream};
use std::thread;
use std::time::Duration;

use leonardo_twin::campaign::{run_sweep_streaming, SweepGrid};
use leonardo_twin::coordinator::Twin;
use leonardo_twin::service::{
    drain, run_worker, run_worker_io, serve_listener, submit, CoordinatorConfig, FaultPlan,
    FaultyTransport, SweepSpec, WireFault, WorkerOptions,
};

/// 12 scenarios → 12 singleton work groups: enough that every fleet
/// member owns several, small enough to churn through quickly.
fn chaos_grid() -> SweepGrid {
    SweepGrid::new(
        vec![1, 2, 3],
        vec![None, Some(7.0)],
        vec!["day".into(), "ai".into()],
        60,
    )
    .unwrap()
}

fn spec(twin: &Twin, grid: &SweepGrid) -> SweepSpec {
    SweepSpec {
        grid: grid.clone(),
        routing: twin.net.routing,
        fork: false,
    }
}

fn snappy_cfg(expect: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        expect,
        heartbeat: Duration::from_millis(50),
        deadline_floor: Duration::from_millis(700),
        ..CoordinatorConfig::default()
    }
}

fn fleet_opts(id: &str) -> WorkerOptions {
    WorkerOptions {
        poll: Duration::from_millis(25),
        patience: Duration::from_secs(20),
        ..WorkerOptions::named(id)
    }
}

/// An honest worker whose connection is sabotaged on one side by an
/// explicit fault schedule. Errors are the point: a chaos probe dying
/// mid-protocol is the experiment, not a test failure.
fn sabotaged_worker(
    twin: &Twin,
    addr: std::net::SocketAddr,
    id: &str,
    write_plan: FaultPlan,
    read_plan: FaultPlan,
) {
    let mut wt = twin.clone();
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).ok();
    stream
        .set_read_timeout(Some(Duration::from_millis(25)))
        .unwrap();
    let reader = FaultyTransport::new(stream.try_clone().unwrap(), read_plan);
    let writer = FaultyTransport::new(stream, write_plan);
    let _ = run_worker_io(&mut wt, reader, writer, &fleet_opts(id));
}

/// Every write-side fault kind, pinned at operation 4 — the length
/// prefix of w1's *first* `RowBatch` frame. With no pings in flight
/// (the config below stretches the heartbeat past the test) the pull
/// protocol's write sequence is fully deterministic — `Hello` (ops
/// 0–1), `Next` (2–3), `RowBatch` (4–5) — and at op 4 the probe still
/// holds its whole credit window unacked, so the fault always lands on
/// owed work. Each kind is detected through a different path — dropped
/// link (EOF), truncated frame (stalled partial frame), corrupt byte
/// (garbage length prefix), long delay (per-class progress deadline,
/// which ticks independently of the heartbeat) — and every path
/// converges on the same outcome: exactly one worker lost, zero rows
/// of the sabotaged batch merged, the report byte-identical.
#[test]
fn every_write_fault_kind_costs_one_worker_and_zero_report_bytes() {
    let twin = Twin::leonardo();
    let grid = chaos_grid();
    let oracle = run_sweep_streaming(&twin, &grid, 2);
    let sp = spec(&twin, &grid);

    for fault in [
        WireFault::Drop,
        WireFault::TruncateWrite,
        WireFault::CorruptByte,
        WireFault::DelayMs(1_500),
    ] {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        // No pings (heartbeat outlives the test) so the write-op
        // positions are exact; the progress-deadline clock still runs
        // every service tick and convicts the stalled batch.
        let cfg = CoordinatorConfig {
            heartbeat: Duration::from_secs(60),
            ..snappy_cfg(2)
        };
        let (report, stats) = thread::scope(|s| {
            let mut wt = twin.clone();
            s.spawn(move || {
                let sock = TcpStream::connect(addr).unwrap();
                run_worker(&mut wt, sock, &fleet_opts("w0")).unwrap()
            });
            let twin = &twin;
            s.spawn(move || {
                sabotaged_worker(
                    twin,
                    addr,
                    "w1",
                    FaultPlan::at(&[(4, fault)]),
                    FaultPlan::at(&[]),
                )
            });
            serve_listener(listener, Some(&sp), &cfg).unwrap()
        });
        let report = report.expect("initial grid always yields its report");
        assert_eq!(oracle, report, "{fault:?} perturbed the report");
        assert_eq!(stats.workers_joined, 2, "{fault:?}: join accounting");
        assert_eq!(stats.workers_lost, 1, "{fault:?}: the probe was not convicted");
        assert_eq!(stats.jobs_served, 1, "{fault:?}: job accounting");
        assert_eq!(stats.duplicate_rows, 0, "{fault:?}: a sabotaged batch merged twice");
    }
}

/// Read-side faults: the probe's incoming half dies or corrupts, the
/// worker bails with a clear error, and the coordinator sees an
/// ordinary connection loss. (Whether the loss lands before or after
/// the probe's last ack depends on ping timing, so the loss count is
/// bounded, not pinned.)
#[test]
fn read_side_faults_never_perturb_the_report() {
    let twin = Twin::leonardo();
    let grid = chaos_grid();
    let oracle = run_sweep_streaming(&twin, &grid, 2);
    let sp = spec(&twin, &grid);

    for fault in [WireFault::Drop, WireFault::CorruptByte] {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let cfg = snappy_cfg(2);
        let (report, stats) = thread::scope(|s| {
            let mut wt = twin.clone();
            s.spawn(move || {
                let sock = TcpStream::connect(addr).unwrap();
                run_worker(&mut wt, sock, &fleet_opts("w0")).unwrap()
            });
            let twin = &twin;
            s.spawn(move || {
                sabotaged_worker(
                    twin,
                    addr,
                    "w1",
                    FaultPlan::at(&[]),
                    FaultPlan::at(&[(6, fault)]),
                )
            });
            serve_listener(listener, Some(&sp), &cfg).unwrap()
        });
        let report = report.expect("initial grid always yields its report");
        assert_eq!(oracle, report, "read-side {fault:?} perturbed the report");
        assert_eq!(stats.workers_joined, 2);
        assert!(stats.workers_lost <= 1, "read-side {fault:?} lost the fleet");
        assert_eq!(stats.jobs_served, 1);
    }
}

/// The production probe path: `WorkerOptions::chaos` (CLI `--chaos`)
/// derives independent read/write fault schedules from the seed. For
/// several seeds, a three-member fleet with one probe finishes the
/// sweep byte-identically, losing at most the probe.
#[test]
fn seeded_chaos_probes_cost_at_most_themselves() {
    let twin = Twin::leonardo();
    let grid = chaos_grid();
    let oracle = run_sweep_streaming(&twin, &grid, 2);
    let sp = spec(&twin, &grid);

    for seed in [1u64, 2, 3] {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let cfg = snappy_cfg(3);
        let (report, stats) = thread::scope(|s| {
            for k in 0..2 {
                let mut wt = twin.clone();
                s.spawn(move || {
                    let sock = TcpStream::connect(addr).unwrap();
                    run_worker(&mut wt, sock, &fleet_opts(&format!("w{k}"))).unwrap()
                });
            }
            let mut wt = twin.clone();
            s.spawn(move || {
                let sock = TcpStream::connect(addr).unwrap();
                let opts = WorkerOptions {
                    chaos: Some(seed),
                    ..fleet_opts("wc")
                };
                // The probe dying mid-protocol is the experiment.
                let _ = run_worker(&mut wt, sock, &opts);
            });
            serve_listener(listener, Some(&sp), &cfg).unwrap()
        });
        let report = report.expect("initial grid always yields its report");
        assert_eq!(oracle, report, "chaos seed {seed} perturbed the report");
        assert_eq!(stats.workers_joined, 3, "chaos seed {seed}: join accounting");
        assert!(
            stats.workers_lost <= 1,
            "chaos seed {seed} took an honest worker down too"
        );
        assert_eq!(stats.jobs_served, 1);
    }
}

/// The acceptance-shaped chaos run: a persistent coordinator serves a
/// three-job queue — initial grid plus two client submissions — while
/// one fleet member is a seeded chaos probe. Whenever and however the
/// probe dies (or survives), every report is byte-identical and the
/// honest workers are never convicted.
#[test]
fn a_chaos_probe_cannot_perturb_a_multi_job_queue() {
    let twin = Twin::leonardo();
    let grid1 = chaos_grid();
    let grid2 = SweepGrid::new(vec![1, 2], vec![None], vec!["day".into()], 50).unwrap();
    let grid3 = SweepGrid::new(vec![3], vec![None, Some(6.5)], vec!["ai".into()], 40).unwrap();
    let o1 = run_sweep_streaming(&twin, &grid1, 2);
    let o2 = run_sweep_streaming(&twin, &grid2, 2);
    let o3 = run_sweep_streaming(&twin, &grid3, 2);
    let sp1 = spec(&twin, &grid1);
    let sp2 = spec(&twin, &grid2);
    let sp3 = spec(&twin, &grid3);

    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();
    let cfg = CoordinatorConfig {
        queue_cap: 4,
        persist: true,
        ..snappy_cfg(3)
    };

    let (r1, stats, r2, r3) = thread::scope(|s| {
        let serve = s.spawn(|| serve_listener(listener, Some(&sp1), &cfg));
        for k in 0..2 {
            let mut wt = twin.clone();
            s.spawn(move || {
                let sock = TcpStream::connect(addr).unwrap();
                run_worker(&mut wt, sock, &fleet_opts(&format!("w{k}"))).unwrap()
            });
        }
        {
            let mut wt = twin.clone();
            s.spawn(move || {
                let sock = TcpStream::connect(addr).unwrap();
                let opts = WorkerOptions {
                    chaos: Some(42),
                    ..fleet_opts("wc")
                };
                let _ = run_worker(&mut wt, sock, &opts);
            });
        }
        let c2 = s.spawn(|| submit(addr, &sp2, Duration::from_secs(30)).unwrap());
        let c3 = s.spawn(|| submit(addr, &sp3, Duration::from_secs(30)).unwrap());
        let r2 = c2.join().unwrap();
        let r3 = c3.join().unwrap();
        assert_eq!(drain(addr, Duration::from_secs(10)).unwrap(), 0);
        let (r1, stats) = serve.join().unwrap().unwrap();
        (r1.expect("initial grid always yields its report"), stats, r2, r3)
    });

    assert_eq!(o1, r1, "chaos perturbed the initial job");
    assert_eq!(o2, r2, "chaos perturbed queued job 2");
    assert_eq!(o3, r3, "chaos perturbed queued job 3");
    assert_eq!(stats.workers_joined, 3);
    assert_eq!(stats.jobs_served, 3);
    assert_eq!(stats.jobs_rejected, 0);
    assert!(
        stats.workers_lost <= 1,
        "chaos took an honest worker down too"
    );
}
