//! Distributed-sweep acceptance: the coordinator + worker-fleet service
//! produces reports byte-identical to the single-process engines for
//! any fleet size — including the policy, fault and fork axes — and
//! survives real failure: crashed workers, stalled-but-connected
//! workers timed out by the progress deadline, lying acks, duplicate
//! acks, bounded-queue overload and coordinator restarts, all without
//! perturbing a single report byte.
//!
//! Every test here runs the real service: a TCP listener on an
//! ephemeral loopback port, worker threads speaking the length-prefixed
//! JSON protocol, the consistent-hash ring and the grid-index slot
//! merge. Nothing is mocked, and nothing sleeps — misbehaving peers are
//! convicted by the same heartbeat and deadline clocks production runs.

use std::io::Read as _;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::thread;
use std::time::{Duration, Instant};

use leonardo_twin::campaign::{
    replay_group, run_sweep_forked, run_sweep_streaming, CampaignReport, SweepGrid,
};
use leonardo_twin::coordinator::Twin;
use leonardo_twin::scheduler::{Coupling, PolicyKind};
use leonardo_twin::service::messages::{read_msg, read_msg_patient, write_msg};
use leonardo_twin::service::{
    drain, run_distributed, run_distributed_cfg, run_fleet, run_worker, run_worker_resilient,
    serve_listener, submit, CoordinatorConfig, DispatchMode, HashRing, Msg, ServiceStats,
    SweepSpec, WorkerOptions, DEFAULT_REPLICAS,
};
use leonardo_twin::workloads::FaultTrace;

/// The canonical 24-scenario grid the benches and CI gate run.
fn canonical_grid() -> SweepGrid {
    SweepGrid::new(
        vec![1, 2, 3, 4],
        vec![None, Some(7.5), Some(6.0)],
        vec!["day".into(), "ai".into()],
        100,
    )
    .unwrap()
}

/// A 12-scenario grid whose fork-off work groups are 12 singletons —
/// small enough to churn quickly, large enough that every fleet member
/// owns several groups.
fn churn_grid() -> SweepGrid {
    SweepGrid::new(
        vec![1, 2, 3],
        vec![None, Some(7.0)],
        vec!["day".into(), "ai".into()],
        60,
    )
    .unwrap()
}

fn spec(twin: &Twin, grid: &SweepGrid, fork: bool) -> SweepSpec {
    SweepSpec {
        grid: grid.clone(),
        routing: twin.net.routing,
        fork,
    }
}

/// Coordinator tuning for liveness tests: real heartbeat and deadline
/// clocks, just fast enough that convicting a stalled peer takes a
/// fraction of a second instead of the production half-minute.
fn snappy_cfg(expect: usize, floor: Duration) -> CoordinatorConfig {
    CoordinatorConfig {
        expect,
        heartbeat: Duration::from_millis(50),
        deadline_floor: floor,
        ..CoordinatorConfig::default()
    }
}

/// Worker tuning to match [`snappy_cfg`]: poll often, but stay patient
/// about coordinator silence for the whole test.
fn fleet_opts(id: &str) -> WorkerOptions {
    WorkerOptions {
        poll: Duration::from_millis(25),
        patience: Duration::from_secs(20),
        ..WorkerOptions::named(id)
    }
}

/// Static-dispatch variant of a config: the tests below that predict
/// exact group ownership from the ring (or hand-roll a worker that
/// waits for an unsolicited `Assign`) pin the PR 8 dispatcher; the
/// adaptive pull path is exercised by everything else plus the
/// threaded/straggler tests.
fn static_dispatch(cfg: CoordinatorConfig) -> CoordinatorConfig {
    CoordinatorConfig {
        dispatch: DispatchMode::Static,
        ..cfg
    }
}

/// Rebuild the coordinator's ring locally so tests can predict exactly
/// which groups each fleet member owns.
fn ring_of(names: &[&str]) -> HashRing {
    let mut ring = HashRing::new(DEFAULT_REPLICAS);
    for n in names {
        ring.add(n);
    }
    ring
}

fn owned_by(ring: &HashRing, n_groups: usize, who: &str) -> Vec<usize> {
    (0..n_groups)
        .filter(|&g| ring.assign_group(g).unwrap() == who)
        .collect()
}

/// A worker that joins the fleet and then never speaks again: it
/// swallows every frame the coordinator sends (so the socket stays
/// healthy from the coordinator's side) but streams no rows, acks no
/// groups and answers no pings — detectable only by the deadline
/// clocks. Returns when the coordinator severs the connection.
fn stalled_peer(addr: SocketAddr, name: &str) {
    let mut sock = TcpStream::connect(addr).unwrap();
    write_msg(
        &mut sock,
        &Msg::Hello {
            worker: name.to_string(),
        },
    )
    .unwrap();
    let mut buf = [0u8; 1024];
    loop {
        match sock.read(&mut buf) {
            Ok(0) | Err(_) => return, // severed: the coordinator gave up on us
            Ok(_) => {}
        }
    }
}

/// Acceptance criterion: 1-, 2- and 4-worker fleets all emit the exact
/// report the in-process streaming engine does — sharding, the wire
/// format and the slot merge are invisible in the output.
#[test]
fn distributed_report_is_identical_for_any_fleet_size() {
    let twin = Twin::leonardo();
    let grid = canonical_grid();
    assert_eq!(grid.len(), 24);
    let oracle = run_sweep_streaming(&twin, &grid, 2);
    for workers in [1, 2, 4] {
        let report = twin.sweep_distributed(&grid, false, workers).unwrap();
        assert_eq!(oracle, report, "{workers}-worker distributed sweep diverged");
        assert_eq!(
            oracle.scenario_table().to_markdown(),
            report.scenario_table().to_markdown(),
            "{workers}-worker rendered table diverged"
        );
    }
}

/// A quiet fleet reports clean service stats: everyone joined, nobody
/// lost, nothing reassigned, no duplicate or stale rows, exactly one
/// job served.
#[test]
fn healthy_fleet_reports_clean_service_stats() {
    let twin = Twin::leonardo();
    let grid = SweepGrid::new(vec![1, 2], vec![None], vec!["day".into()], 60).unwrap();
    let sp = spec(&twin, &grid, false);
    let (_, stats) = run_distributed(&twin, &sp, 3, &[]).unwrap();
    assert_eq!(
        stats,
        ServiceStats {
            workers_joined: 3,
            jobs_served: 1,
            ..ServiceStats::default()
        }
    );
}

/// The policy and fault axes ride through the wire untouched: a
/// coupled grid crossing two placement policies with two fault traces
/// merges byte-identically to the streaming oracle.
#[test]
fn distributed_matches_streaming_on_policy_and_fault_axes() {
    let twin = Twin::leonardo();
    let faulted = FaultTrace {
        seed: 7,
        duration_s: 86_400.0,
        node_mtbf_s: 200_000.0,
        repair_mean_s: 7_200.0,
        group: 4,
        link_mtbf_s: 400_000.0,
        link_repair_mean_s: 3_600.0,
        degraded_factor: 0.5,
    };
    let grid = SweepGrid::new(vec![1, 2], vec![None, Some(7.0)], vec!["day".into()], 80)
        .unwrap()
        .with_coupling(Coupling::full())
        .with_policies(vec![PolicyKind::PackFirst, PolicyKind::SpreadLinks])
        .with_fault_traces(vec![FaultTrace::none(), faulted]);
    assert_eq!(grid.len(), 2 * 2 * 2 * 2);
    let oracle = run_sweep_streaming(&twin, &grid, 2);
    for workers in [2, 3] {
        let report = twin.sweep_distributed(&grid, false, workers).unwrap();
        assert_eq!(oracle, report, "{workers}-worker policy/fault sweep diverged");
    }
}

/// Fork mode: workers replay divergence-tree groups on their arenas
/// (snapshot at the cap fork point, restore per sibling) and the
/// merged report — fork/restore counters included — is byte-identical
/// to `run_sweep_forked` at every fleet size.
#[test]
fn distributed_fork_mode_matches_the_forked_oracle() {
    let twin = Twin::leonardo();
    let grid = canonical_grid()
        .with_coupling(Coupling::full())
        .with_cap_time(20_000.0);
    let oracle = run_sweep_forked(&twin, &grid, 2);
    for workers in [1, 2, 4] {
        let report = twin.sweep_distributed(&grid, true, workers).unwrap();
        assert_eq!(oracle, report, "{workers}-worker forked sweep diverged");
    }
    // The fork actually happened on the workers' side of the wire.
    assert!(oracle.stats.iter().all(|s| s.forks == 1));
}

/// Churn under static dispatch: one of three workers dies mid-sweep.
/// The ring hands exactly its unacknowledged groups to the survivors,
/// the merge backfills them, and the final report is still
/// byte-identical to the single-process oracle.
#[test]
fn worker_churn_reassigns_only_the_lost_workers_groups() {
    let twin = Twin::leonardo();
    let grid = churn_grid();
    assert_eq!(grid.len(), 12);

    // Reproduce the dispatch ring locally so the die-after arithmetic
    // below is visible: w0 owns exactly groups {5, 6} of this grid.
    let ring = ring_of(&["w0", "w1", "w2"]);
    let w0_groups = owned_by(&ring, grid.len(), "w0");
    assert_eq!(w0_groups, vec![5, 6], "pinned ring layout moved");

    // w0 acknowledges one group then drops its connection, orphaning
    // the other. Only that one group may move.
    let oracle = run_sweep_streaming(&twin, &grid, 2);
    let sp = spec(&twin, &grid, false);
    let cfg = static_dispatch(CoordinatorConfig::default());
    let (report, stats) = run_distributed_cfg(&twin, &sp, 3, &[(0, 1)], &cfg).unwrap();
    assert_eq!(oracle, report, "churned sweep diverged from the oracle");
    assert_eq!(stats.workers_joined, 3);
    assert_eq!(stats.workers_lost, 1);
    assert_eq!(
        stats.groups_reassigned,
        w0_groups.len() - 1,
        "re-dispatch touched groups the lost worker had already acked"
    );
    assert_eq!(stats.duplicate_rows, 0);

    // Ring-level guarantee behind the service behavior: dropping w0
    // moves only w0's groups; every survivor keeps its assignment.
    let mut after = ring.clone();
    after.remove("w0");
    for g in 0..grid.len() {
        let owner = ring.assign_group(g).unwrap();
        if owner != "w0" {
            assert_eq!(
                after.assign_group(g).unwrap(),
                owner,
                "group {g} moved although its owner survived"
            );
        } else {
            assert_ne!(after.assign_group(g), Some("w0"));
        }
    }
}

/// Losing every worker must not hang the coordinator: with the whole
/// fleet gone mid-sweep and rows outstanding, the merge loop bails
/// with a diagnostic instead of waiting forever.
#[test]
fn losing_the_entire_fleet_errors_instead_of_hanging() {
    let twin = Twin::leonardo();
    let grid = churn_grid();
    let sp = spec(&twin, &grid, false);
    // The single worker dies after one of its twelve groups.
    let err = run_distributed(&twin, &sp, 1, &[(0, 1)]).unwrap_err();
    let msg = format!("{err}");
    assert!(
        msg.contains("fleet lost"),
        "unexpected fleet-loss diagnostic: {msg}"
    );
}

/// A stalled worker — connected, joined, silent — cannot hide behind
/// its open socket: the progress deadline convicts it, its groups are
/// re-dispatched to the survivors, and the report is byte-identical.
#[test]
fn a_stalled_worker_is_timed_out_and_its_groups_reassigned() {
    let twin = Twin::leonardo();
    let grid = churn_grid();
    let oracle = run_sweep_streaming(&twin, &grid, 2);
    let sp = spec(&twin, &grid, false);

    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();
    let cfg = static_dispatch(snappy_cfg(3, Duration::from_millis(700)));

    let (report, stats) = thread::scope(|s| {
        for k in 0..2 {
            let mut wt = twin.clone();
            s.spawn(move || {
                let sock = TcpStream::connect(addr).unwrap();
                run_worker(&mut wt, sock, &fleet_opts(&format!("w{k}"))).unwrap()
            });
        }
        s.spawn(move || stalled_peer(addr, "w2"));
        serve_listener(listener, Some(&sp), &cfg).unwrap()
    });
    let report = report.expect("initial grid always yields its report");

    let ring = ring_of(&["w0", "w1", "w2"]);
    let stalled = owned_by(&ring, grid.len(), "w2");
    assert!(!stalled.is_empty(), "pinned ring layout moved");
    assert_eq!(oracle, report, "stalled-worker sweep diverged");
    assert_eq!(stats.workers_joined, 3);
    assert_eq!(stats.workers_lost, 1, "the stalled worker was not convicted");
    assert_eq!(
        stats.groups_reassigned,
        stalled.len(),
        "re-dispatch did not match the stalled worker's unacked groups"
    );
    assert_eq!(stats.duplicate_rows, 0);
    assert_eq!(stats.jobs_served, 1);
    // Its groups were held from dispatch until the deadline fired.
    assert!(stats.reassign_latency_mean_s > 0.0);
    assert!(stats.reassign_latency_max_s >= stats.reassign_latency_mean_s);
}

/// Protocol-edge robustness: a worker that streams junk rows (unknown
/// grid index, bygone job id) and then acks a group that does not
/// exist is expelled — the junk never merges, the lying ack never
/// wedges the sweep, and the survivor finishes byte-identically.
#[test]
fn a_lying_ack_and_junk_rows_expel_the_worker_without_merging() {
    let twin = Twin::leonardo();
    let grid = churn_grid();
    let oracle = run_sweep_streaming(&twin, &grid, 2);
    let sp = spec(&twin, &grid, false);
    let junk = oracle.stats[0].clone();

    let ring = ring_of(&["w0", "w1"]);
    let liars_groups = owned_by(&ring, grid.len(), "w1");
    assert!(!liars_groups.is_empty(), "pinned ring layout moved");

    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();
    let cfg = static_dispatch(snappy_cfg(2, Duration::from_millis(700)));

    let (report, stats) = thread::scope(|s| {
        let mut wt = twin.clone();
        s.spawn(move || {
            let sock = TcpStream::connect(addr).unwrap();
            run_worker(&mut wt, sock, &fleet_opts("w0")).unwrap()
        });
        let junk = junk.clone();
        s.spawn(move || {
            let mut sock = TcpStream::connect(addr).unwrap();
            sock.set_read_timeout(Some(Duration::from_millis(25))).unwrap();
            write_msg(&mut sock, &Msg::Hello { worker: "w1".into() }).unwrap();
            let deadline = Instant::now() + Duration::from_secs(20);
            let mut lied = false;
            loop {
                assert!(Instant::now() < deadline, "liar never got its assignment");
                match read_msg_patient(&mut sock, Duration::from_secs(5)) {
                    Ok(Some(Msg::Assign { job, .. })) if !lied => {
                        // Junk row: an index outside the grid.
                        write_msg(
                            &mut sock,
                            &Msg::Row {
                                job,
                                index: 10_000,
                                stats: junk.clone(),
                            },
                        )
                        .unwrap();
                        // Stale row: a job id nobody is running.
                        write_msg(
                            &mut sock,
                            &Msg::Row {
                                job: job + 1,
                                index: 0,
                                stats: junk.clone(),
                            },
                        )
                        .unwrap();
                        // The lie: ack a group that does not exist.
                        write_msg(&mut sock, &Msg::GroupDone { job, group: 10_000 }).unwrap();
                        lied = true;
                    }
                    Ok(_) => {}
                    Err(_) => break, // severed: the coordinator expelled us
                }
            }
            assert!(lied, "liar was severed before it could misbehave");
        });
        serve_listener(listener, Some(&sp), &cfg).unwrap()
    });
    let report = report.expect("initial grid always yields its report");

    assert_eq!(oracle, report, "the junk rows leaked into the report");
    assert_eq!(stats.workers_joined, 2);
    assert_eq!(stats.workers_lost, 1, "the liar kept its seat");
    assert_eq!(stats.stale_rows, 2, "junk rows were not counted as stale");
    assert_eq!(
        stats.groups_reassigned,
        liars_groups.len(),
        "the liar's groups did not all move to the survivor"
    );
    assert_eq!(stats.duplicate_rows, 0);
}

/// A duplicate `GroupDone` — a worker acking the same group twice — is
/// a clean no-op: no expulsion, no reassignment, no double-merge.
#[test]
fn duplicate_group_acks_are_a_clean_no_op() {
    let twin = Twin::leonardo();
    let grid = SweepGrid::new(vec![1, 2], vec![None], vec!["day".into()], 60).unwrap();
    let oracle = run_sweep_streaming(&twin, &grid, 2);
    let sp = spec(&twin, &grid, false);

    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();
    let cfg = static_dispatch(snappy_cfg(1, Duration::from_millis(700)));

    let (report, stats) = thread::scope(|s| {
        let mut wt = twin.clone();
        s.spawn(move || {
            // A hand-rolled honest worker that double-acks every group.
            let mut sock = TcpStream::connect(addr).unwrap();
            sock.set_read_timeout(Some(Duration::from_millis(25))).unwrap();
            write_msg(&mut sock, &Msg::Hello { worker: "w0".into() }).unwrap();
            let mut arena = None;
            let mut cur = None;
            loop {
                match read_msg_patient(&mut sock, Duration::from_secs(10)).unwrap() {
                    Some(Msg::Spec { job, spec }) => {
                        wt.net.routing = spec.routing;
                        cur = Some((job, spec.grid.scenarios(), spec.grid.work_groups(spec.fork)));
                    }
                    Some(Msg::Assign { job, groups }) => {
                        let (id, scenarios, work) =
                            cur.as_ref().expect("assignment before its spec");
                        assert_eq!(job, *id);
                        for g in groups {
                            let members = &work[g as usize];
                            for (index, stats) in
                                replay_group(&mut arena, &wt, scenarios, members)
                            {
                                write_msg(
                                    &mut sock,
                                    &Msg::Row {
                                        job: *id,
                                        index: index as u64,
                                        stats,
                                    },
                                )
                                .unwrap();
                            }
                            write_msg(&mut sock, &Msg::GroupDone { job: *id, group: g }).unwrap();
                            // The duplicate the coordinator must shrug off.
                            write_msg(&mut sock, &Msg::GroupDone { job: *id, group: g }).unwrap();
                        }
                    }
                    Some(Msg::Ping) => write_msg(&mut sock, &Msg::Pong).unwrap(),
                    Some(Msg::Shutdown) => break,
                    Some(other) => panic!("unexpected {other:?}"),
                    None => {}
                }
            }
        });
        serve_listener(listener, Some(&sp), &cfg).unwrap()
    });
    let report = report.expect("initial grid always yields its report");

    assert_eq!(oracle, report, "double-acked sweep diverged");
    assert_eq!(
        stats,
        ServiceStats {
            workers_joined: 1,
            jobs_served: 1,
            ..ServiceStats::default()
        },
        "a duplicate ack was not a no-op"
    );
}

/// The job queue is bounded: with one job active and the queue at
/// capacity, a further `Submit` is rejected immediately — the client
/// gets a reason, not a hang — while the accepted jobs still run to
/// byte-identical reports once the fleet forms.
#[test]
fn the_job_queue_is_bounded_and_rejects_rather_than_parks() {
    let twin = Twin::leonardo();
    let grid_a = SweepGrid::new(vec![1, 2], vec![None], vec!["day".into()], 50).unwrap();
    let grid_b = SweepGrid::new(vec![3], vec![None, Some(6.5)], vec!["ai".into()], 40).unwrap();
    let oracle_a = run_sweep_streaming(&twin, &grid_a, 2);
    let oracle_b = run_sweep_streaming(&twin, &grid_b, 2);
    let sp_a = spec(&twin, &grid_a, false);
    let sp_b = spec(&twin, &grid_b, false);

    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();
    let cfg = CoordinatorConfig {
        queue_cap: 1,
        persist: true,
        ..snappy_cfg(2, Duration::from_millis(700))
    };

    fn raw_submit(addr: SocketAddr, sp: &SweepSpec) -> Result<(TcpStream, u64), String> {
        let mut sock = TcpStream::connect(addr).unwrap();
        sock.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        write_msg(&mut sock, &Msg::Submit { spec: sp.clone() }).unwrap();
        match read_msg(&mut sock).unwrap() {
            Msg::Accepted { job } => Ok((sock, job)),
            Msg::Rejected { reason } => Err(reason),
            other => panic!("unexpected {other:?} as a submission verdict"),
        }
    }

    fn await_report(sock: &mut TcpStream, job: u64) -> CampaignReport {
        match read_msg(sock).unwrap() {
            Msg::Report { job: id, report } if id == job => report,
            other => panic!("unexpected {other:?} while awaiting job {job}"),
        }
    }

    thread::scope(|s| {
        let serve = s.spawn(|| serve_listener(listener, None, &cfg));

        // No workers yet: job 1 goes active (undispatched), job 2 fills
        // the queue, job 3 must bounce.
        let (mut ca, ja) = raw_submit(addr, &sp_a).expect("first submission fits");
        let (mut cb, jb) = raw_submit(addr, &sp_b).expect("second submission fits");
        assert_eq!((ja, jb), (1, 2));
        let reason = raw_submit(addr, &sp_a).expect_err("third submission must bounce");
        assert!(reason.contains("queue full"), "wrong rejection: {reason}");

        // Now let the fleet form and the queue drain, FIFO.
        for k in 0..2 {
            let mut wt = twin.clone();
            s.spawn(move || {
                let sock = TcpStream::connect(addr).unwrap();
                run_worker(&mut wt, sock, &fleet_opts(&format!("w{k}"))).unwrap()
            });
        }
        let ra = await_report(&mut ca, ja);
        let rb = await_report(&mut cb, jb);
        assert_eq!(ra, oracle_a, "queued job 1 diverged");
        assert_eq!(rb, oracle_b, "queued job 2 diverged");

        // Everything is merged; the drain has nothing left to wait on.
        assert_eq!(drain(addr, Duration::from_secs(10)).unwrap(), 0);
        let (initial, stats) = serve.join().unwrap().unwrap();
        assert!(initial.is_none(), "a grid-less coordinator invented a report");
        assert_eq!(
            stats,
            ServiceStats {
                workers_joined: 2,
                jobs_served: 2,
                jobs_rejected: 1,
                ..ServiceStats::default()
            }
        );
    });
}

/// Satellite: a resilient worker outlives its coordinator. The first
/// incarnation of the coordinator dies on accept; the worker backs
/// off, reconnects under the same identity, and serves the whole
/// sweep on the second incarnation.
#[test]
fn a_resilient_worker_rejoins_after_a_coordinator_restart() {
    let twin = Twin::leonardo();
    let grid = SweepGrid::new(vec![1, 2], vec![None], vec!["day".into()], 50).unwrap();
    let oracle = run_sweep_streaming(&twin, &grid, 2);
    let sp = spec(&twin, &grid, false);

    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();
    let cfg = snappy_cfg(1, Duration::from_millis(700));

    let (report, stats, acked) = thread::scope(|s| {
        let worker = s.spawn(move || {
            let mut wt = twin.clone();
            run_worker_resilient(&mut wt, addr, &fleet_opts("w0"), Duration::from_secs(20))
                .unwrap()
        });
        // First incarnation: accept the worker's connection and die.
        let (doomed, _) = listener.accept().unwrap();
        drop(doomed);
        // Second incarnation: the same listener, now actually serving.
        let (report, stats) = serve_listener(listener, Some(&sp), &cfg).unwrap();
        (report, stats, worker.join().unwrap())
    });
    let report = report.expect("initial grid always yields its report");

    assert_eq!(oracle, report, "post-restart sweep diverged");
    assert_eq!(
        stats,
        ServiceStats {
            workers_joined: 1,
            jobs_served: 1,
            ..ServiceStats::default()
        }
    );
    assert_eq!(acked, grid.len(), "the rejoined worker did not serve every group");
}

/// The headline acceptance test: a four-worker fleet where one worker
/// crashes mid-job and another stalls silently serves a three-job
/// submission queue — initial grid plus two `Submit`s — to completion.
/// Both failures are convicted (`workers_lost == 2`), exactly the
/// unacknowledged groups move, and all three reports are byte-identical
/// to the single-process engine.
#[test]
fn a_churned_fleet_serves_a_three_job_queue_byte_identically() {
    let twin = Twin::leonardo();
    let grid1 = churn_grid();
    let grid2 = SweepGrid::new(vec![1, 2], vec![None], vec!["day".into()], 50).unwrap();
    let grid3 = SweepGrid::new(vec![3], vec![None, Some(6.5)], vec!["ai".into()], 40).unwrap();
    let o1 = run_sweep_streaming(&twin, &grid1, 2);
    let o2 = run_sweep_streaming(&twin, &grid2, 2);
    let o3 = run_sweep_streaming(&twin, &grid3, 2);
    let sp1 = spec(&twin, &grid1, false);
    let sp2 = spec(&twin, &grid2, false);
    let sp3 = spec(&twin, &grid3, false);

    let n_groups = grid1.work_groups(false).len();
    let ring0 = ring_of(&["w0", "w1", "w2", "w3"]);
    let w2g = owned_by(&ring0, n_groups, "w2");
    let w3g = owned_by(&ring0, n_groups, "w3");
    assert!(!w2g.is_empty() && !w3g.is_empty(), "pinned ring layout moved");
    // w2 acks its first (lowest-id) group, then crashes: the rest are
    // its orphans. They re-dispatch over {w0, w1, w3}; whatever lands
    // on the stalled w3 is orphaned a second time when the deadline
    // convicts it, alongside w3's own groups.
    let w2_orphans = &w2g[1..];
    let mut ring1 = ring0.clone();
    ring1.remove("w2");
    let inherited = w2_orphans
        .iter()
        .filter(|&&g| ring1.assign_group(g).unwrap() == "w3")
        .count();
    let expected_reassigned = w2_orphans.len() + w3g.len() + inherited;

    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();
    let cfg = static_dispatch(CoordinatorConfig {
        queue_cap: 4,
        persist: true,
        ..snappy_cfg(4, Duration::from_millis(800))
    });

    let (r1, stats, r2, r3) = thread::scope(|s| {
        let serve = s.spawn(|| serve_listener(listener, Some(&sp1), &cfg));
        for k in 0..2 {
            let mut wt = twin.clone();
            s.spawn(move || {
                let sock = TcpStream::connect(addr).unwrap();
                run_worker(&mut wt, sock, &fleet_opts(&format!("w{k}"))).unwrap()
            });
        }
        // w2: a real crash — one acked group, then the socket drops.
        {
            let mut wt = twin.clone();
            s.spawn(move || {
                let sock = TcpStream::connect(addr).unwrap();
                let opts = WorkerOptions {
                    die_after_groups: Some(1),
                    ..fleet_opts("w2")
                };
                run_worker(&mut wt, sock, &opts).unwrap()
            });
        }
        // w3: joined but silent for the rest of its life.
        s.spawn(move || stalled_peer(addr, "w3"));

        // Two client submissions ride the queue behind the initial grid.
        let c2 = s.spawn(|| submit(addr, &sp2, Duration::from_secs(30)).unwrap());
        let c3 = s.spawn(|| submit(addr, &sp3, Duration::from_secs(30)).unwrap());
        let r2 = c2.join().unwrap();
        let r3 = c3.join().unwrap();

        // All reports are out; drain shuts the service down cleanly.
        assert_eq!(drain(addr, Duration::from_secs(10)).unwrap(), 0);
        let (r1, stats) = serve.join().unwrap().unwrap();
        (r1.expect("initial grid always yields its report"), stats, r2, r3)
    });

    assert_eq!(o1, r1, "churned job 1 diverged from the oracle");
    assert_eq!(o2, r2, "queued job 2 diverged from the oracle");
    assert_eq!(o3, r3, "queued job 3 diverged from the oracle");
    assert_eq!(stats.workers_joined, 4);
    assert_eq!(stats.workers_lost, 2, "crash + stall must both be convicted");
    assert_eq!(stats.jobs_served, 3);
    assert_eq!(stats.jobs_rejected, 0);
    assert_eq!(stats.duplicate_rows, 0);
    assert_eq!(stats.stale_rows, 0);
    assert_eq!(
        stats.groups_reassigned, expected_reassigned,
        "re-dispatch did not match the two losses' unacked groups"
    );
    // The stalled worker's groups were hostage until the deadline fired.
    assert!(stats.reassign_latency_max_s > 0.5);
    assert!(stats.reassign_latency_mean_s > 0.0);
    assert!(stats.reassign_latency_max_s >= stats.reassign_latency_mean_s);
}

/// Tentpole acceptance: adaptive pull dispatch, multi-thread worker
/// arenas and batched `RowBatch` frames are invisible in the output.
/// The streaming and forked oracles are reproduced byte-for-byte at
/// several (fleet size × thread count) shapes — policy and fault axes
/// included — and the starvation counter pins the no-idle invariant.
#[test]
fn pull_dispatch_with_threaded_workers_matches_the_oracles() {
    let twin = Twin::leonardo();
    let faulted = FaultTrace {
        seed: 7,
        duration_s: 86_400.0,
        node_mtbf_s: 200_000.0,
        repair_mean_s: 7_200.0,
        group: 4,
        link_mtbf_s: 400_000.0,
        link_repair_mean_s: 3_600.0,
        degraded_factor: 0.5,
    };
    let grid = SweepGrid::new(vec![1, 2], vec![None, Some(7.0)], vec!["day".into()], 80)
        .unwrap()
        .with_coupling(Coupling::full())
        .with_policies(vec![PolicyKind::PackFirst, PolicyKind::SpreadLinks])
        .with_fault_traces(vec![FaultTrace::none(), faulted]);
    let oracle = run_sweep_streaming(&twin, &grid, 2);
    let sp = spec(&twin, &grid, false);
    for (workers, threads) in [(2, 4), (3, 2)] {
        let (report, stats) =
            run_fleet(&twin, &sp, workers, threads, &[], &CoordinatorConfig::default())
                .unwrap();
        assert_eq!(oracle, report, "{workers}x{threads} pull sweep diverged");
        assert_eq!(stats.workers_lost, 0);
        assert_eq!(stats.starved_ticks, 0, "a credited worker idled with work queued");
    }

    // Fork mode: whole divergence trees replay on pool arenas, each
    // group's rows and ack riding one RowBatch frame.
    let forked = canonical_grid()
        .with_coupling(Coupling::full())
        .with_cap_time(20_000.0);
    let oracle = run_sweep_forked(&twin, &forked, 2);
    let sp = spec(&twin, &forked, true);
    let (report, stats) =
        run_fleet(&twin, &sp, 2, 4, &[], &CoordinatorConfig::default()).unwrap();
    assert_eq!(oracle, report, "threaded forked pull sweep diverged");
    assert_eq!(stats.workers_lost, 0);
    assert_eq!(stats.starved_ticks, 0);
    assert!(report.stats.iter().all(|s| s.forks == 1));
}

/// The straggler test the tentpole exists for: a skewed grid — faulted
/// fork groups cost a multiple of clean ones — served by three workers
/// running three different prefetch depths. Adaptive pull keeps every
/// worker fed until the queue runs dry: all three replay at least one
/// group, no service tick observes a credited worker idling beside
/// queued work, and the merged report is byte-identical to the forked
/// oracle at every prefetch depth.
#[test]
fn skewed_grid_keeps_every_worker_fed_regardless_of_prefetch_depth() {
    let twin = Twin::leonardo();
    let faulted = FaultTrace {
        seed: 11,
        duration_s: 86_400.0,
        node_mtbf_s: 150_000.0,
        repair_mean_s: 7_200.0,
        group: 4,
        link_mtbf_s: 300_000.0,
        link_repair_mean_s: 3_600.0,
        degraded_factor: 0.5,
    };
    let grid = SweepGrid::new(
        vec![1, 2, 3, 4],
        vec![None, Some(7.0), Some(6.5)],
        vec!["day".into()],
        40,
    )
    .unwrap()
    .with_coupling(Coupling::full())
    .with_cap_time(20_000.0)
    .with_fault_traces(vec![FaultTrace::none(), faulted]);
    let n_groups = grid.work_groups(true).len();
    assert_eq!(n_groups, 8, "4 seeds x 2 fault traces, one fork group each");
    let oracle = run_sweep_forked(&twin, &grid, 2);
    let sp = spec(&twin, &grid, true);

    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();
    let cfg = CoordinatorConfig {
        expect: 3,
        ..CoordinatorConfig::default()
    };

    let (report, stats, acked) = thread::scope(|s| {
        let fleet: Vec<_> = (0..3usize)
            .map(|k| {
                let mut wt = twin.clone();
                s.spawn(move || {
                    let sock = TcpStream::connect(addr).unwrap();
                    let opts = WorkerOptions {
                        prefetch: k + 1,
                        ..fleet_opts(&format!("w{k}"))
                    };
                    run_worker(&mut wt, sock, &opts).unwrap()
                })
            })
            .collect();
        let (report, stats) = serve_listener(listener, Some(&sp), &cfg).unwrap();
        let acked: Vec<usize> = fleet.into_iter().map(|h| h.join().unwrap()).collect();
        (report, stats, acked)
    });
    let report = report.expect("initial grid always yields its report");

    assert_eq!(oracle, report, "skewed pull sweep diverged from the forked oracle");
    assert_eq!(stats.workers_joined, 3);
    assert_eq!(stats.workers_lost, 0);
    assert_eq!(stats.starved_ticks, 0, "a credited worker idled beside queued work");
    assert_eq!(
        acked.iter().sum::<usize>(),
        n_groups,
        "every group must be acked exactly once: {acked:?}"
    );
    assert!(
        acked.iter().all(|&a| a >= 1),
        "pull dispatch left a worker idle for the whole sweep: {acked:?}"
    );
}
