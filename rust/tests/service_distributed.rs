//! Distributed-sweep acceptance: the coordinator + worker-fleet service
//! produces reports byte-identical to the single-process engines for
//! any fleet size — including the policy, fault and fork axes — and
//! worker churn mid-sweep reassigns exactly the lost worker's
//! unacknowledged groups without perturbing the report.
//!
//! Every test here runs the real service: a TCP listener on an
//! ephemeral loopback port, worker threads speaking the length-prefixed
//! JSON protocol, the consistent-hash ring and the grid-index slot
//! merge. Nothing is mocked.

use leonardo_twin::campaign::{run_sweep_forked, run_sweep_streaming, SweepGrid};
use leonardo_twin::coordinator::Twin;
use leonardo_twin::scheduler::{Coupling, PolicyKind};
use leonardo_twin::service::{run_distributed, HashRing, ServiceStats, SweepSpec, DEFAULT_REPLICAS};
use leonardo_twin::workloads::FaultTrace;

/// The canonical 24-scenario grid the benches and CI gate run.
fn canonical_grid() -> SweepGrid {
    SweepGrid::new(
        vec![1, 2, 3, 4],
        vec![None, Some(7.5), Some(6.0)],
        vec!["day".into(), "ai".into()],
        100,
    )
    .unwrap()
}

fn spec(twin: &Twin, grid: &SweepGrid, fork: bool) -> SweepSpec {
    SweepSpec {
        grid: grid.clone(),
        routing: twin.net.routing,
        fork,
    }
}

/// Acceptance criterion: 1-, 2- and 4-worker fleets all emit the exact
/// report the in-process streaming engine does — sharding, the wire
/// format and the slot merge are invisible in the output.
#[test]
fn distributed_report_is_identical_for_any_fleet_size() {
    let twin = Twin::leonardo();
    let grid = canonical_grid();
    assert_eq!(grid.len(), 24);
    let oracle = run_sweep_streaming(&twin, &grid, 2);
    for workers in [1, 2, 4] {
        let report = twin.sweep_distributed(&grid, false, workers).unwrap();
        assert_eq!(oracle, report, "{workers}-worker distributed sweep diverged");
        assert_eq!(
            oracle.scenario_table().to_markdown(),
            report.scenario_table().to_markdown(),
            "{workers}-worker rendered table diverged"
        );
    }
}

/// A quiet fleet reports clean service stats: everyone joined, nobody
/// lost, nothing reassigned, no duplicate rows merged.
#[test]
fn healthy_fleet_reports_clean_service_stats() {
    let twin = Twin::leonardo();
    let grid = SweepGrid::new(vec![1, 2], vec![None], vec!["day".into()], 60).unwrap();
    let sp = spec(&twin, &grid, false);
    let (_, stats) = run_distributed(&twin, &sp, 3, &[]).unwrap();
    assert_eq!(
        stats,
        ServiceStats {
            workers_joined: 3,
            workers_lost: 0,
            groups_reassigned: 0,
            duplicate_rows: 0,
        }
    );
}

/// The policy and fault axes ride through the wire untouched: a
/// coupled grid crossing two placement policies with two fault traces
/// merges byte-identically to the streaming oracle.
#[test]
fn distributed_matches_streaming_on_policy_and_fault_axes() {
    let twin = Twin::leonardo();
    let faulted = FaultTrace {
        seed: 7,
        duration_s: 86_400.0,
        node_mtbf_s: 200_000.0,
        repair_mean_s: 7_200.0,
        group: 4,
        link_mtbf_s: 400_000.0,
        link_repair_mean_s: 3_600.0,
        degraded_factor: 0.5,
    };
    let grid = SweepGrid::new(vec![1, 2], vec![None, Some(7.0)], vec!["day".into()], 80)
        .unwrap()
        .with_coupling(Coupling::full())
        .with_policies(vec![PolicyKind::PackFirst, PolicyKind::SpreadLinks])
        .with_fault_traces(vec![FaultTrace::none(), faulted]);
    assert_eq!(grid.len(), 2 * 2 * 2 * 2);
    let oracle = run_sweep_streaming(&twin, &grid, 2);
    for workers in [2, 3] {
        let report = twin.sweep_distributed(&grid, false, workers).unwrap();
        assert_eq!(oracle, report, "{workers}-worker policy/fault sweep diverged");
    }
}

/// Fork mode: workers replay divergence-tree groups on their arenas
/// (snapshot at the cap fork point, restore per sibling) and the
/// merged report — fork/restore counters included — is byte-identical
/// to `run_sweep_forked` at every fleet size.
#[test]
fn distributed_fork_mode_matches_the_forked_oracle() {
    let twin = Twin::leonardo();
    let grid = canonical_grid()
        .with_coupling(Coupling::full())
        .with_cap_time(20_000.0);
    let oracle = run_sweep_forked(&twin, &grid, 2);
    for workers in [1, 2, 4] {
        let report = twin.sweep_distributed(&grid, true, workers).unwrap();
        assert_eq!(oracle, report, "{workers}-worker forked sweep diverged");
    }
    // The fork actually happened on the workers' side of the wire.
    assert!(oracle.stats.iter().all(|s| s.forks == 1));
}

/// Churn: one of three workers dies mid-sweep. The ring hands exactly
/// its unacknowledged groups to the survivors, the merge backfills
/// them, and the final report is still byte-identical to the
/// single-process oracle.
#[test]
fn worker_churn_reassigns_only_the_lost_workers_groups() {
    let twin = Twin::leonardo();
    // 12 scenarios, fork off → 12 singleton groups g0..g11.
    let grid = SweepGrid::new(
        vec![1, 2, 3],
        vec![None, Some(7.0)],
        vec!["day".into(), "ai".into()],
        60,
    )
    .unwrap();
    assert_eq!(grid.len(), 12);

    // Reproduce the dispatch ring locally so the die-after arithmetic
    // below is visible: w0 owns exactly groups {5, 6} of this grid.
    let mut ring = HashRing::new(DEFAULT_REPLICAS);
    for w in ["w0", "w1", "w2"] {
        ring.add(w);
    }
    let w0_groups: Vec<usize> = (0..grid.len())
        .filter(|&g| ring.assign_group(g).unwrap() == "w0")
        .collect();
    assert_eq!(w0_groups, vec![5, 6], "pinned ring layout moved");

    // w0 acknowledges one group then drops its connection, orphaning
    // the other. Only that one group may move.
    let oracle = run_sweep_streaming(&twin, &grid, 2);
    let sp = spec(&twin, &grid, false);
    let (report, stats) = run_distributed(&twin, &sp, 3, &[(0, 1)]).unwrap();
    assert_eq!(oracle, report, "churned sweep diverged from the oracle");
    assert_eq!(stats.workers_joined, 3);
    assert_eq!(stats.workers_lost, 1);
    assert_eq!(
        stats.groups_reassigned,
        w0_groups.len() - 1,
        "re-dispatch touched groups the lost worker had already acked"
    );
    assert_eq!(stats.duplicate_rows, 0);

    // Ring-level guarantee behind the service behavior: dropping w0
    // moves only w0's groups; every survivor keeps its assignment.
    let mut after = ring.clone();
    after.remove("w0");
    for g in 0..grid.len() {
        let owner = ring.assign_group(g).unwrap();
        if owner != "w0" {
            assert_eq!(
                after.assign_group(g).unwrap(),
                owner,
                "group {g} moved although its owner survived"
            );
        } else {
            assert_ne!(after.assign_group(g), Some("w0"));
        }
    }
}

/// Losing every worker must not hang the coordinator: with the whole
/// fleet gone mid-sweep and rows outstanding, the merge loop bails
/// with a diagnostic instead of waiting forever.
#[test]
fn losing_the_entire_fleet_errors_instead_of_hanging() {
    let twin = Twin::leonardo();
    let grid = SweepGrid::new(
        vec![1, 2, 3],
        vec![None, Some(7.0)],
        vec!["day".into(), "ai".into()],
        60,
    )
    .unwrap();
    let sp = spec(&twin, &grid, false);
    // The single worker dies after one of its twelve groups.
    let err = run_distributed(&twin, &sp, 1, &[(0, 1)]).unwrap_err();
    let msg = format!("{err}");
    assert!(
        msg.contains("fleet lost"),
        "unexpected fleet-loss diagnostic: {msg}"
    );
}
