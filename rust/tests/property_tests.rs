//! Property-based tests over randomized inputs (deterministic SplitMix64
//! sweeps — the offline build carries no proptest, so these are explicit
//! generate-and-check loops with fixed seeds and wide case counts).

use leonardo_twin::config::MachineConfig;
use leonardo_twin::lbm::decompose_3d;
use leonardo_twin::network::{Network, Placement};
use leonardo_twin::power::{cap_scale, DvfsPoint, PowerModel, Utilization};
use leonardo_twin::scheduler::{CheckpointPolicy, Job, Partition, Scheduler};
use leonardo_twin::storage::{StorageSystem, Stripe};
use leonardo_twin::topology::{Routing, Topology};
use leonardo_twin::util::json::Json;
use leonardo_twin::util::rng::Rng;

// ---------------------------------------------------------------------
// scheduler invariants
// ---------------------------------------------------------------------

/// Random job streams: every job completes, never exceeds capacity,
/// respects submit times, and the machine drains back to fully free.
#[test]
fn prop_scheduler_random_streams() {
    let cfg = MachineConfig::leonardo();
    for seed in 0..8u64 {
        let mut rng = Rng::new(seed);
        let n_jobs = rng.range_u32(5, 60);
        let jobs: Vec<Job> = (0..n_jobs)
            .map(|i| {
                let booster = rng.f64() < 0.7;
                Job {
                    id: i as u64,
                    partition: if booster {
                        Partition::Booster
                    } else {
                        Partition::DataCentric
                    },
                    nodes: rng.range_u32(1, if booster { 3456 } else { 1536 }),
                    est_seconds: rng.range_f64(1.0, 500.0),
                    run_seconds: rng.range_f64(1.0, 500.0),
                    submit_time: rng.range_f64(0.0, 100.0),
                    boundness: rng.f64(),
                    comm_fraction: rng.f64() * 0.5,
                    checkpoint: CheckpointPolicy::None,
                }
            })
            .collect();
        let mut sched = Scheduler::new(&cfg);
        let recs = sched.run(jobs.clone());
        assert_eq!(recs.len(), jobs.len(), "seed {seed}: lost jobs");
        for j in &jobs {
            let r = &recs[&j.id];
            assert!(r.start_time >= j.submit_time - 1e-9, "seed {seed}");
            assert!(r.end_time > r.start_time, "seed {seed}");
            assert_eq!(r.placement.total_nodes(), j.nodes, "seed {seed}");
        }
        assert_eq!(sched.free_nodes(Partition::Booster), 3456);
        assert_eq!(sched.free_nodes(Partition::DataCentric), 1536);

        // No instant may oversubscribe either partition: sweep events.
        for part in [Partition::Booster, Partition::DataCentric] {
            let cap = sched.total_nodes(part);
            let mut events: Vec<(f64, i64)> = Vec::new();
            for j in &jobs {
                if j.partition != part {
                    continue;
                }
                let r = &recs[&j.id];
                events.push((r.start_time, j.nodes as i64));
                events.push((r.end_time, -(j.nodes as i64)));
            }
            events.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            let mut load = 0i64;
            for (_, delta) in events {
                load += delta;
                assert!(load <= cap as i64, "seed {seed}: oversubscribed");
            }
        }
    }
}

/// Placement is exact and release is the inverse of place.
#[test]
fn prop_place_release_roundtrip() {
    let cfg = MachineConfig::leonardo();
    let mut rng = Rng::new(99);
    for _ in 0..200 {
        let mut sched = Scheduler::new(&cfg);
        let n = rng.range_u32(1, 3456);
        let p = sched.place(Partition::Booster, n).unwrap();
        assert_eq!(p.total_nodes(), n);
        assert_eq!(sched.free_nodes(Partition::Booster), 3456 - n);
        sched.release(Partition::Booster, &p);
        assert_eq!(sched.free_nodes(Partition::Booster), 3456);
    }
}

// ---------------------------------------------------------------------
// network invariants
// ---------------------------------------------------------------------

fn leo_net() -> Network {
    let cfg = MachineConfig::leonardo();
    let inj = cfg.gpu_node_spec().unwrap().injection_gbps();
    Network::new(Topology::build(&cfg), inj)
}

/// Latency is symmetric, bounded by the paper's budget, and minimal
/// routing never beats the NIC floor.
#[test]
fn prop_latency_symmetric_and_bounded() {
    let net = leo_net();
    let total = net.topo.total_nodes();
    let mut rng = Rng::new(5);
    for _ in 0..500 {
        let a = rng.range_u32(0, total - 1);
        let b = rng.range_u32(0, total - 1);
        for policy in [Routing::Minimal, Routing::Valiant] {
            let ab = net.topo.route(a, b, policy).latency_ns();
            let ba = net.topo.route(b, a, policy).latency_ns();
            assert_eq!(ab, ba, "asymmetric {a}<->{b}");
            assert!(ab >= 1200.0, "below NIC floor");
            assert!(ab <= 3000.0, "above paper bound: {ab}");
        }
    }
}

/// Effective bandwidth never exceeds injection and never collapses below
/// half of it for packed placements.
#[test]
fn prop_effective_bw_bounds() {
    let net = leo_net();
    let mut rng = Rng::new(17);
    for _ in 0..300 {
        let k = rng.range_u32(1, 19);
        let per = rng.range_u32(1, 180);
        let placement = Placement {
            nodes_per_cell: (0..k).map(|c| (c, per)).collect(),
        };
        let bw = net.effective_node_bw(&placement);
        assert!(bw <= net.injection_gbs() + 1e-9);
        assert!(bw >= 0.4 * net.injection_gbs(), "collapse: k={k} per={per} {bw}");
    }
}

/// Halo + allreduce are monotone in payload and node count direction.
#[test]
fn prop_collectives_monotone() {
    let net = leo_net();
    let mut rng = Rng::new(23);
    for _ in 0..100 {
        let k = rng.range_u32(1, 8);
        let per = rng.range_u32(2, 180);
        let p = Placement {
            nodes_per_cell: (0..k).map(|c| (c, per)).collect(),
        };
        let b1 = rng.range_u32(1, 1 << 20) as u64;
        let b2 = b1 * 2;
        assert!(net.halo_exchange_time(&p, 6, b2) >= net.halo_exchange_time(&p, 6, b1));
        assert!(net.allreduce_time(&p, b2) >= net.allreduce_time(&p, b1));
        assert!(net.halo_exchange_time(&p, 6, b1) >= net.halo_exchange_time(&p, 2, b1));
    }
}

// ---------------------------------------------------------------------
// storage invariants
// ---------------------------------------------------------------------

/// Striped file bandwidth is monotone in stripe count, capped by client
/// link and pool capability.
#[test]
fn prop_striping_bounds() {
    let sys = StorageSystem::leonardo();
    let mut rng = Rng::new(31);
    for ns in &sys.namespaces {
        let mut last = 0.0f64;
        for count in 1..=64u32 {
            let link = rng.range_f64(1.0, 100.0);
            let bw = Stripe {
                count,
                size_mib: 16,
            }
            .file_bw_gbs(1e9, ns, false);
            assert!(bw >= last - 1e-9, "{}: stripe {count}", ns.mount);
            assert!(bw <= ns.peak_read_gbs() + 1e-9);
            last = bw;
            // Client-limited variant never exceeds the link.
            let capped = Stripe {
                count,
                size_mib: 16,
            }
            .file_bw_gbs(link, ns, false);
            assert!(capped <= link + 1e-9);
        }
    }
}

// ---------------------------------------------------------------------
// power invariants
// ---------------------------------------------------------------------

/// Capping is sound: the returned scale always satisfies the cap, and
/// tighter caps give lower scales.
#[test]
fn prop_power_cap_soundness() {
    let model = PowerModel::new(
        leonardo_twin::hardware::NodeSpec::davinci(),
        1.1,
    );
    let u = Utilization::hpl();
    let idle = model.node_power_w(Utilization::idle());
    let dynamic = model.node_power_w(u) - idle;
    let mut rng = Rng::new(41);
    let mut last_scale = 0.0f64;
    let uncapped = model.fleet_power_mw(3300, u);
    for i in 0..50 {
        let cap = uncapped * (0.55 + 0.009 * i as f64);
        if let Some(p) = cap_scale(&model, 3300, u, cap) {
            let power = 3300.0 * (idle + dynamic * p.power_factor()) / 1e6;
            assert!(power <= cap * 1.001, "cap {cap}: {power}");
            assert!(p.scale >= last_scale - 1e-9, "monotone in cap");
            last_scale = p.scale;
        }
        let _ = rng.next_u64();
    }
}

/// Reported facility energy equals the integral of the
/// `facility_power_w` series — step integral exactly (the draw is
/// piecewise-constant between event samples), trapezoid as a sanity
/// bound — with and without a facility power cap, coupled and not.
#[test]
fn prop_energy_equals_power_series_integral() {
    use leonardo_twin::hardware::NodeSpec;
    use leonardo_twin::power::PowerMonitor;
    use leonardo_twin::scheduler::{Coupling, PowerCap};
    use leonardo_twin::sim::{Component, Event, ScheduledEvent};
    use leonardo_twin::workloads::TraceGen;

    let cfg = MachineConfig::leonardo();
    let model = PowerModel::new(NodeSpec::davinci(), 1.1);
    let cases: [(Option<f64>, Coupling); 3] = [
        (None, Coupling::default()),
        (Some(5.5), Coupling::default()),
        (Some(5.5), Coupling::full()),
    ];
    for (cap_mw, coupling) in cases {
        let jobs = TraceGen::booster_day(400, 7).generate();
        let mut sched = Scheduler::with_coupling(&cfg, coupling);
        if let Some(mw) = cap_mw {
            sched.power_cap = Some(PowerCap::for_model(&model, mw));
        }
        let mut monitor = PowerMonitor::new(model.clone(), Utilization::hpl(), 3456);
        monitor.booster_only = true;
        // A mid-day cap move exercises the Retime path when coupled.
        let extra = match cap_mw {
            Some(mw) => vec![ScheduledEvent::at(
                20_000.0,
                Event::CapChange {
                    cap_mw: Some(mw * 0.8),
                },
            )],
            None => Vec::new(),
        };
        let mut observers: [&mut dyn Component; 1] = [&mut monitor];
        let recs = sched.run_with(jobs, extra, &mut observers);
        assert_eq!(recs.len(), 400);

        let series = monitor.store.get("facility_power_w").unwrap();
        // Independent re-integration from the raw samples.
        let mut step_j = 0.0;
        let mut trapezoid_j = 0.0;
        let mut prev: Option<(f64, f64)> = None;
        for s in series.samples() {
            if let Some((t0, v0)) = prev {
                step_j += v0 * (s.t - t0);
                trapezoid_j += 0.5 * (v0 + s.value) * (s.t - t0);
            }
            prev = Some((s.t, s.value));
        }
        let reported = monitor.energy_kwh();
        assert!(
            (reported - step_j / 3.6e6).abs() <= 1e-9 * step_j.abs().max(1.0),
            "cap {cap_mw:?}: reported {reported} vs step {}",
            step_j / 3.6e6
        );
        // The trapezoid of the same series stays within a few percent —
        // it smears each step over its segment but sees the same levels.
        let trap_kwh = trapezoid_j / 3.6e6;
        assert!(
            (reported - trap_kwh).abs() / trap_kwh.max(1e-9) < 0.10,
            "cap {cap_mw:?}: step {reported} vs trapezoid {trap_kwh}"
        );
        assert!(reported > 0.0);
    }
}

/// Link-load conservation: at every event on the stream, the per-link
/// cross counts a [`CongestionTracker`] maintains equal the sum over
/// running multi-cell jobs of their per-route contributions
/// ([`link_contributions`]) — per bundle and in total — under both
/// routings, with and without a mid-day `CapChange`, and the table
/// drains to zero when the day ends.
#[test]
fn prop_link_load_conservation() {
    use leonardo_twin::network::{link_contributions, CongestionTracker};
    use leonardo_twin::scheduler::{Coupling, PowerCap};
    use leonardo_twin::sim::{Component, Event, ScheduledEvent};
    use leonardo_twin::workloads::TraceGen;
    use std::collections::BTreeMap;

    /// Forwards events to an inner tracker, re-derives the expected
    /// link table from its own running-job set, and asserts equality
    /// after every event.
    struct Checker {
        tracker: CongestionTracker,
        running: BTreeMap<u64, Vec<(u32, u32)>>,
        events_checked: u64,
    }

    impl Component for Checker {
        fn on_event(&mut self, now: f64, ev: &Event, out: &mut Vec<ScheduledEvent>) {
            self.tracker.on_event(now, ev, out);
            match ev {
                Event::Start { job, booster: true, cells, .. } if cells.len() > 1 => {
                    self.running.insert(*job, cells.to_vec());
                }
                Event::End { job, booster: true, cells, .. } if cells.len() > 1 => {
                    self.running.remove(job);
                }
                _ => return,
            }
            let mut expected: BTreeMap<(u32, u32), u64> = BTreeMap::new();
            for cells in self.running.values() {
                for ((a, b), nodes) in link_contributions(cells) {
                    *expected.entry((a, b)).or_insert(0) += nodes as u64;
                }
            }
            let expected_total: u64 = expected.values().sum();
            assert_eq!(
                self.tracker.total_link_cross_nodes(),
                expected_total,
                "link-load sum diverged at t={now}"
            );
            for (&(a, b), &nodes) in &expected {
                assert_eq!(
                    self.tracker.link_cross_nodes(a, b) as u64,
                    nodes,
                    "bundle ({a}, {b}) diverged at t={now}"
                );
            }
            self.events_checked += 1;
        }
    }

    let cfg = MachineConfig::leonardo();
    for routing in [Routing::Minimal, Routing::Valiant, Routing::Adaptive] {
        for mid_day_cap in [false, true] {
            let jobs = TraceGen::booster_hpc_day(300, 11).generate();
            let mut sched = Scheduler::with_coupling(&cfg, Coupling::full());
            if let Some(net) = sched.net.as_mut() {
                net.routing = routing;
            }
            sched.power_cap = Some(PowerCap {
                cap_mw: 99.0,
                node_watts: 2238.0,
                idle_watts: 365.0,
            });
            let extra = if mid_day_cap {
                vec![ScheduledEvent::at(20_000.0, Event::CapChange { cap_mw: Some(5.5) })]
            } else {
                Vec::new()
            };
            let mut checker = Checker {
                tracker: CongestionTracker::for_booster(&cfg),
                running: BTreeMap::new(),
                events_checked: 0,
            };
            let recs = {
                let mut observers: [&mut dyn Component; 1] = [&mut checker];
                sched.run_with(jobs, extra, &mut observers)
            };
            let ctx = format!("routing {routing:?} cap {mid_day_cap}");
            assert_eq!(recs.len(), 300, "{ctx}");
            assert!(checker.events_checked > 0, "{ctx}: no multi-cell lifecycle event checked");
            assert!(checker.running.is_empty(), "{ctx}: jobs left running");
            assert_eq!(
                checker.tracker.total_link_cross_nodes(),
                0,
                "{ctx}: link table did not drain"
            );
            assert!(checker.tracker.peak_link_load() > 0.0, "{ctx}: no load seen");
        }
    }
}

/// Snapshot round trip: open a session, run to a mid-day fork point,
/// snapshot, *perturb* (inject a divergent cap move and keep
/// simulating), restore, replay the real suffix — and land bit-for-bit
/// on a fresh replay of the same scenario. Exercised across both
/// engines (incremental and retime-all), both routings, coupling on
/// and off, with and without a mid-day `CapChange` (injected into the
/// ranked divergent band after the restore, exactly as the forked
/// sweep does). The counter equality pins that restoring the
/// generation stamps keeps stale-`End` skips — `events_skipped` —
/// report-neutral.
#[test]
fn prop_snapshot_restore_replay_is_bit_identical() {
    use leonardo_twin::hardware::NodeSpec;
    use leonardo_twin::network::CongestionTracker;
    use leonardo_twin::power::PowerMonitor;
    use leonardo_twin::scheduler::{Coupling, JobRecord, PowerCap, ReplaySession};
    use leonardo_twin::sim::{Component, Event, ScheduledEvent, Simulation};
    use leonardo_twin::workloads::TraceGen;
    use std::collections::BTreeMap;

    const T_FORK: f64 = 20_000.0;
    let cfg = MachineConfig::leonardo();
    let model = PowerModel::new(NodeSpec::davinci(), 1.1);

    let assert_records = |a: &BTreeMap<u64, JobRecord>, b: &BTreeMap<u64, JobRecord>, tag: &str| {
        assert_eq!(a.len(), b.len(), "{tag}: record counts differ");
        for (id, ra) in a {
            let rb = &b[id];
            assert_eq!(ra.start_time, rb.start_time, "{tag}: job {id} start");
            assert_eq!(ra.end_time, rb.end_time, "{tag}: job {id} end");
            assert_eq!(ra.dvfs_scale, rb.dvfs_scale, "{tag}: job {id} scale");
            assert_eq!(
                ra.placement.nodes_per_cell, rb.placement.nodes_per_cell,
                "{tag}: job {id} placement"
            );
        }
    };

    for coupling in [Coupling::default(), Coupling::full()] {
        for routing in [Routing::Minimal, Routing::Valiant] {
            for retime_all in [false, true] {
                for mid_cap in [false, true] {
                    let tag = format!(
                        "coupled={} routing={routing:?} retime_all={retime_all} mid_cap={mid_cap}",
                        coupling.enabled()
                    );
                    let jobs = TraceGen::booster_hpc_day(200, 13).generate();
                    let mk_sched = || {
                        let mut s = Scheduler::with_coupling(&cfg, coupling);
                        s.retime_all = retime_all;
                        if let Some(net) = s.net.as_mut() {
                            net.routing = routing;
                        }
                        if mid_cap {
                            // Armed but infinite: bit-identical to
                            // capless until the mid-day move lands.
                            s.power_cap = Some(PowerCap::for_model(&model, f64::INFINITY));
                        }
                        s
                    };
                    let mk_monitor = || {
                        let mut m =
                            PowerMonitor::new(model.clone(), Utilization::hpl(), 3456);
                        m.booster_only = true;
                        m
                    };
                    let cap_move = Event::CapChange { cap_mw: Some(5.5) };

                    // Fresh replay: the oracle. The cap move rides the
                    // divergent band from t=0 (rank 0).
                    let mut sim_b = Simulation::new();
                    let mut sched_b = mk_sched();
                    let mut monitor_b = mk_monitor();
                    let mut tracker_b = CongestionTracker::for_booster(&cfg);
                    let extra = if mid_cap {
                        vec![ScheduledEvent::at(T_FORK, cap_move.clone())]
                    } else {
                        Vec::new()
                    };
                    let mut session =
                        ReplaySession::new(&mut sim_b, &mut sched_b, jobs.clone(), extra);
                    {
                        let mut obs: [&mut dyn Component; 2] =
                            [&mut monitor_b, &mut tracker_b];
                        session.run_to_end(&mut obs);
                    }
                    let recs_b = session.finish();

                    // Forked replay: prefix, snapshot, perturb (a cap
                    // move the real scenario never sees, plus more
                    // simulated day), restore, inject the real cap
                    // move at the same rank the fresh path used.
                    let mut sim_f = Simulation::new();
                    let mut sched_f = mk_sched();
                    let mut monitor_f = mk_monitor();
                    let mut tracker_f = CongestionTracker::for_booster(&cfg);
                    let mut session =
                        ReplaySession::new(&mut sim_f, &mut sched_f, jobs.clone(), Vec::new());
                    {
                        let mut obs: [&mut dyn Component; 2] =
                            [&mut monitor_f, &mut tracker_f];
                        session.run_until(T_FORK, &mut obs);
                        session.snapshot(&mut obs);
                        session.schedule_ranked(
                            T_FORK + 1_000.0,
                            Event::CapChange { cap_mw: Some(4.0) },
                            7,
                        );
                        session.run_until(2.0 * T_FORK, &mut obs);
                        session.restore(&mut obs);
                        if mid_cap {
                            session.schedule_ranked(T_FORK, cap_move, 0);
                        }
                        session.run_to_end(&mut obs);
                    }
                    let recs_f = session.finish();

                    assert_records(&recs_b, &recs_f, &tag);
                    assert_eq!(
                        sched_b.last_run, sched_f.last_run,
                        "{tag}: skip/elision counters diverged"
                    );
                    assert_eq!(
                        monitor_b.energy_kwh(),
                        monitor_f.energy_kwh(),
                        "{tag}: energy diverged"
                    );
                    let sb = monitor_b.store.get("facility_power_w").unwrap();
                    let sf = monitor_f.store.get("facility_power_w").unwrap();
                    assert_eq!(sb.samples().len(), sf.samples().len(), "{tag}: series len");
                    for (x, y) in sb.samples().iter().zip(sf.samples()) {
                        assert_eq!((x.t, x.value), (y.t, y.value), "{tag}: series sample");
                    }
                    assert_eq!(
                        tracker_b.peak_link_load(),
                        tracker_f.peak_link_load(),
                        "{tag}: peak link load diverged"
                    );
                    assert_eq!(tracker_f.total_link_cross_nodes(), 0, "{tag}: did not drain");
                }
            }
        }
    }
}

/// DVFS time factor: slowing clocks never speeds a job up; memory-bound
/// jobs suffer less.
#[test]
fn prop_dvfs_time_factor() {
    let mut rng = Rng::new(47);
    for _ in 0..500 {
        let s = rng.range_f64(0.5, 1.0);
        let b1 = rng.f64();
        let b2 = (b1 + rng.f64() * (1.0 - b1)).min(1.0);
        let p = DvfsPoint { scale: s };
        assert!(p.time_factor(b1) >= 1.0 - 1e-12);
        assert!(p.time_factor(b2) >= p.time_factor(b1) - 1e-12);
    }
}

// ---------------------------------------------------------------------
// misc invariants
// ---------------------------------------------------------------------

/// 3-D decomposition is exact for every n and near-balanced for cubes.
#[test]
fn prop_decompose_exact() {
    let mut rng = Rng::new(53);
    for _ in 0..2000 {
        let n = rng.range_u32(1, 10_000);
        let (x, y, z) = decompose_3d(n);
        assert_eq!(
            x as u64 * y as u64 * z as u64,
            n as u64,
            "decompose_3d({n})"
        );
    }
    for e in [1u32, 2, 3, 4, 5, 8, 10] {
        let n = e * e * e;
        assert_eq!(decompose_3d(n), (e, e, e));
    }
}

/// JSON parser round-trips machine-generated manifests of random shape.
#[test]
fn prop_json_random_manifests() {
    let mut rng = Rng::new(61);
    for _ in 0..50 {
        let entries = rng.range_u32(1, 8);
        let mut text = String::from("{");
        for i in 0..entries {
            if i > 0 {
                text.push(',');
            }
            let dims = rng.range_u32(0, 4);
            let shape: Vec<String> =
                (0..dims).map(|_| rng.range_u32(1, 512).to_string()).collect();
            text.push_str(&format!(
                "\"m{i}\": {{\"hlo_chars\": {}, \"inputs\": [{{\"dtype\": \"float32\", \"shape\": [{}]}}], \"outputs\": []}}",
                rng.range_u32(1, 1 << 20),
                shape.join(",")
            ));
        }
        text.push('}');
        let v = Json::parse(&text).unwrap();
        assert_eq!(v.as_obj().unwrap().len(), entries as usize);
    }
}

// ---------------------------------------------------------------------
// wire codec invariants (the distributed sweep service's substrate)
// ---------------------------------------------------------------------

/// Randomized `ScenarioStats` survive the wire codec bit-for-bit: every
/// field — u64s past 2^53, subnormal/extreme floats, strings needing
/// escapes, both policies, present and absent caps — round-trips
/// through render → parse → decode exactly. This is the property the
/// distributed service's byte-identity guarantee stands on.
#[test]
fn prop_scenario_stats_round_trip_bit_exact() {
    use leonardo_twin::campaign::{CampaignReport, ScenarioStats};
    use leonardo_twin::scheduler::PolicyKind;
    use leonardo_twin::util::json::{
        report_from_json, report_to_json, stats_from_json, stats_to_json,
    };

    // Any finite bit pattern (NaN payloads can't round-trip through a
    // tagged "nan" string; the codec collapses them, checked below).
    fn finite(rng: &mut Rng) -> f64 {
        loop {
            let v = f64::from_bits(rng.next_u64());
            if v.is_finite() {
                return v;
            }
        }
    }
    // Finite, ±infinity, or exact extremes — everything the tagged
    // codec claims to preserve.
    fn wild(rng: &mut Rng) -> f64 {
        let random_bits = finite(rng);
        *rng.choose(&[
            random_bits,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::MAX,
            f64::MIN_POSITIVE,
            5e-324, // smallest subnormal
            -0.0,
            0.0,
        ])
    }
    fn wild_u64(rng: &mut Rng) -> u64 {
        let random = rng.next_u64();
        *rng.choose(&[
            random,
            0,
            u64::MAX,
            (1 << 53) + 1, // first integer f64 cannot hold
        ])
    }
    let mixes = ["day", "ai", "hpc", "a \"quoted\"\n\tmix", "", "日"];
    let faults = ["none", "mtbf200k+link400k", "\u{1}\u{1f}ctrl"];

    let mut rng = Rng::new(2307);
    let mut batch = Vec::new();
    for case in 0..64 {
        let s = ScenarioStats {
            mix: rng.choose(&mixes).to_string(),
            seed: wild_u64(&mut rng),
            cap_mw: if rng.f64() < 0.5 { None } else { Some(wild(&mut rng)) },
            policy: *rng.choose(&[PolicyKind::PackFirst, PolicyKind::SpreadLinks]),
            faults: rng.choose(&faults).to_string(),
            jobs: wild_u64(&mut rng) as usize,
            makespan_h: wild(&mut rng),
            mean_wait_min: wild(&mut rng),
            p95_wait_min: wild(&mut rng),
            max_wait_min: wild(&mut rng),
            utilization: wild(&mut rng),
            peak_mw: wild(&mut rng),
            energy_mwh: wild(&mut rng),
            throttled: wild_u64(&mut rng) as usize,
            peak_congestion: wild(&mut rng),
            peak_link_util: wild(&mut rng),
            mean_link_util: wild(&mut rng),
            mean_stretch: wild(&mut rng),
            p95_stretch: wild(&mut rng),
            events_skipped: wild_u64(&mut rng),
            retimes_elided: wild_u64(&mut rng),
            forks: wild_u64(&mut rng),
            restores: wild_u64(&mut rng),
            killed: wild_u64(&mut rng),
            requeued: wild_u64(&mut rng),
            wasted_node_h: wild(&mut rng),
            goodput: wild(&mut rng),
            p95_recovery_stretch: wild(&mut rng),
        };
        let text = stats_to_json(&s).render();
        let back = stats_from_json(&Json::parse(&text).unwrap()).unwrap();
        // PartialEq would pass -0.0 == 0.0; compare the bits too.
        assert_eq!(s, back, "case {case}: decoded stats differ");
        assert_eq!(
            s.makespan_h.to_bits(),
            back.makespan_h.to_bits(),
            "case {case}: float bits changed (signed zero?)"
        );
        assert_eq!(
            s.cap_mw.map(f64::to_bits),
            back.cap_mw.map(f64::to_bits),
            "case {case}: cap bits changed"
        );
        batch.push(s);
    }
    // Whole-report codec: order and length preserved.
    let report = CampaignReport { stats: batch };
    let text = report_to_json(&report).render();
    let back = report_from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(report, back, "report codec reordered or dropped rows");

    // NaN is tagged, not silently mangled: it decodes back to NaN
    // (payload collapsed to the canonical quiet NaN).
    let mut s = report.stats[0].clone();
    s.goodput = f64::NAN;
    let text = stats_to_json(&s).render();
    let back = stats_from_json(&Json::parse(&text).unwrap()).unwrap();
    assert!(back.goodput.is_nan(), "NaN lost its tag through the wire");
}
