//! Campaign-sweep acceptance: the same scenario grid merged from 1, 2
//! and 8 worker threads is bit-for-bit identical (work distribution is
//! an atomic cursor, merge is by grid index), the divergence-tree
//! forked engine reproduces streaming byte-for-byte modulo its fork
//! counters, and the grid axes behave (caps throttle, mixes change the
//! load shape, seeds vary arrivals).

use leonardo_twin::campaign::{run_sweep, run_sweep_streaming, SweepGrid};
use leonardo_twin::coordinator::Twin;

/// The acceptance-criteria grid: 4 seeds x 3 caps x 2 mixes = 24
/// scenarios, merged reports identical for 1, 2 and 8 workers.
#[test]
fn merged_report_is_identical_across_thread_counts() {
    let twin = Twin::leonardo();
    let grid = SweepGrid::new(
        vec![1, 2, 3, 4],
        vec![None, Some(7.5), Some(6.0)],
        vec!["day".into(), "ai".into()],
        100,
    )
    .unwrap();
    assert_eq!(grid.len(), 24);
    let r1 = run_sweep(&twin, &grid, 1);
    let r2 = run_sweep(&twin, &grid, 2);
    let r8 = run_sweep(&twin, &grid, 8);
    assert_eq!(r1, r2, "1-thread vs 2-thread reports differ");
    assert_eq!(r1, r8, "1-thread vs 8-thread reports differ");
    assert_eq!(r1.stats.len(), 24);
    // The rendered artifacts (what the CLI prints) are identical too.
    assert_eq!(
        r1.scenario_table().to_markdown(),
        r8.scenario_table().to_markdown()
    );
    assert_eq!(r1.cap_table().to_markdown(), r8.cap_table().to_markdown());
    assert_eq!(
        r1.summary_table().to_markdown(),
        r8.summary_table().to_markdown()
    );
}

/// The streaming engine (per-worker scenario arenas, mpsc merge-as-they-
/// finish) is byte-identical to the join-then-merge path for 1, 2 and 8
/// workers — completion order and rig reuse are invisible in the report.
#[test]
fn streaming_merge_is_identical_to_join_then_merge() {
    let twin = Twin::leonardo();
    let grid = SweepGrid::new(
        vec![1, 2, 3, 4],
        vec![None, Some(7.5), Some(6.0)],
        vec!["day".into(), "ai".into()],
        100,
    )
    .unwrap();
    let joined = run_sweep(&twin, &grid, 4);
    let s1 = run_sweep_streaming(&twin, &grid, 1);
    let s2 = run_sweep_streaming(&twin, &grid, 2);
    let s8 = run_sweep_streaming(&twin, &grid, 8);
    assert_eq!(joined, s1, "1-worker streaming diverged");
    assert_eq!(joined, s2, "2-worker streaming diverged");
    assert_eq!(joined, s8, "8-worker streaming diverged");
    assert_eq!(
        joined.scenario_table().to_markdown(),
        s8.scenario_table().to_markdown()
    );
}

/// The acceptance criterion for the divergence-tree engine: on the
/// 24-scenario cap-axis grid with the cap deferred mid-day, the forked
/// sweep report is byte-identical to `run_sweep_streaming` on the same
/// grid for 1, 2 and 8 workers — modulo the `Forks`/`Restores`
/// bookkeeping, which streaming leaves at zero — and the rendered
/// tables agree after zeroing.
#[test]
fn forked_sweep_is_identical_to_streaming_across_thread_counts() {
    use leonardo_twin::campaign::run_sweep_forked;
    use leonardo_twin::scheduler::Coupling;

    let twin = Twin::leonardo();
    let grid = SweepGrid::new(
        vec![1, 2, 3, 4],
        vec![None, Some(7.5), Some(6.0)],
        vec!["day".into(), "ai".into()],
        100,
    )
    .unwrap()
    .with_coupling(Coupling::full())
    .with_cap_time(20_000.0);
    assert_eq!(grid.len(), 24);
    let streamed = run_sweep_streaming(&twin, &grid, 2);
    for threads in [1, 2, 8] {
        let forked = run_sweep_forked(&twin, &grid, threads);
        let zeroed = forked.with_fork_counters_zeroed();
        assert_eq!(streamed, zeroed, "{threads}-worker forked sweep diverged");
        assert_eq!(
            streamed.scenario_table().to_markdown(),
            zeroed.scenario_table().to_markdown()
        );
        // 8 groups of 3 caps: every scenario rode a shared prefix,
        // and exactly the non-first members paid a restore.
        assert!(forked.stats.iter().all(|s| s.forks == 1), "{threads} workers");
        let restores: u64 = forked.stats.iter().map(|s| s.restores).sum();
        assert_eq!(restores, 16, "{threads} workers");
    }
    // Deferred caps still throttle once they land.
    let throttled: usize = streamed
        .stats
        .iter()
        .filter(|s| s.cap_mw.is_some())
        .map(|s| s.throttled)
        .sum();
    assert!(throttled > 0, "deferred caps never throttled");
}

/// Every scenario of the merged report is internally sane and the grid
/// axes show through: all jobs complete, utilization is a fraction,
/// energy is positive, and different seeds give different days.
#[test]
fn sweep_outcomes_are_sane_and_seed_sensitive() {
    let twin = Twin::leonardo();
    let grid = SweepGrid::new(
        vec![10, 11],
        vec![None],
        vec!["day".into()],
        120,
    )
    .unwrap();
    let report = twin.sweep(&grid, 4);
    assert_eq!(report.stats.len(), 2);
    for s in &report.stats {
        assert_eq!(s.jobs, 120, "{}: lost jobs", s.seed);
        assert!(s.makespan_h > 0.0);
        assert!(s.utilization > 0.0 && s.utilization <= 1.0 + 1e-9);
        assert!(s.energy_mwh > 0.0);
        assert!(s.peak_mw > 0.0);
        assert_eq!(s.throttled, 0, "uncapped scenarios must not throttle");
    }
    assert_ne!(
        report.stats[0].makespan_h, report.stats[1].makespan_h,
        "different seeds should produce different days"
    );
}
